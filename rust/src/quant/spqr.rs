//! SpQR-lite (Dettmers et al., 2023): dense grouped quantization plus a
//! highly-sparse full-precision outlier matrix, stored and executed in
//! **packed** form ([`PackedSpqr`]).
//!
//! The full SpQR quantizes scales/zeros to 3 bits and uses bilevel groups;
//! this lite version keeps the essential mechanism the paper's comparison
//! exercises: weights whose quantization error (weighted by input
//! curvature) is largest are carried exactly, which repairs the group-scale
//! blow-up that outliers cause for RTN/GPTQ. Unlike the earlier
//! dense-backed adapter (which materialized dequantized f32 weights and
//! only *reported* compressed bits through the model's per-layer bits
//! table), the result here is the packed structure itself: bit-packed base
//! codes, per-group scale/zero, and CSR outlier rows with u32 column
//! indices — so `weight_bytes()` reflects the real structural size and the
//! serving path runs the fused sparse kernels in
//! [`kernels::matvec`](crate::kernels::matvec).

use super::gptq::{gptq_quantize, GptqConfig};
use super::{CalibData, QuantizedLayer, Quantizer};
use crate::kernels::format::PackedSpqr;
use crate::nn::linear::Linear;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// SpQR-lite configuration.
#[derive(Clone, Copy, Debug)]
pub struct SpqrConfig {
    /// Integer bit width of the dense base quantization.
    pub bits: usize,
    /// Scale-group size of the base quantization (ragged tails allowed).
    pub group: usize,
    /// Fraction of weights stored as exact outliers (paper uses ~1%).
    pub outlier_frac: f64,
}

impl SpqrConfig {
    /// The paper's SpQR comparison configuration at a given bit width.
    pub fn paper(bits: usize) -> SpqrConfig {
        SpqrConfig { bits, group: 16, outlier_frac: 0.01 }
    }
}

/// [`Quantizer`] adapter for SpQR-lite (spec `spqr:b=B,g=G,out=F`). The
/// result is a [`Linear::Spqr`] backed by the packed storage format, so its
/// `avg_bits` is structural (no dense f32 backing, no reliance on the
/// model's per-layer bits table).
pub struct SpqrQuantizer(pub SpqrConfig);

impl Quantizer for SpqrQuantizer {
    fn name(&self) -> String {
        "SpQR-lite".to_string()
    }

    fn quantize(
        &self,
        w: &Tensor,
        calib: &CalibData,
        _rng: &mut Rng,
    ) -> anyhow::Result<QuantizedLayer> {
        let q = spqr_quantize(w, calib, self.0)?;
        let avg_bits = q.avg_bits();
        Ok(QuantizedLayer { avg_bits, linear: Linear::spqr(q), method: self.name() })
    }
}

/// Quantize with SpQR-lite, returning the packed execution format.
///
/// Base pass: grouped GPTQ at `cfg.bits`/`cfg.group` (ragged tail groups
/// handled). Outlier pass: the `outlier_frac` fraction of weights with the
/// largest curvature-weighted squared error are carried exactly as CSR
/// entries that replace the base dequantization at their positions.
pub fn spqr_quantize(w: &Tensor, calib: &CalibData, cfg: SpqrConfig) -> anyhow::Result<PackedSpqr> {
    let (d_out, d_in) = (w.rows(), w.cols());
    // Base pass: grouped GPTQ.
    let base = gptq_quantize(w, calib, GptqConfig::grouped(cfg.bits, cfg.group))?;
    let dense = base.decode();
    // Sensitivity = squared error × Hessian diagonal (input energy).
    let n_out = ((d_out * d_in) as f64 * cfg.outlier_frac).round() as usize;
    let mut sens: Vec<(f32, usize)> = Vec::with_capacity(d_out * d_in);
    for i in 0..d_out {
        for j in 0..d_in {
            let e = w.at2(i, j) - dense.at2(i, j);
            let s = e * e * calib.xxt.at2(j, j).max(1e-8);
            sens.push((s, i * d_in + j));
        }
    }
    sens.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    // Selected flat indices, re-sorted ascending → CSR rows come out with
    // strictly ascending column indices.
    let mut flats: Vec<usize> = sens.iter().take(n_out).map(|&(_, f)| f).collect();
    flats.sort_unstable();
    let outliers: Vec<(usize, f32)> =
        flats.iter().map(|&f| (f, w.at2(f / d_in, f % d_in))).collect();
    PackedSpqr::from_parts(
        d_out,
        d_in,
        base.group,
        cfg.bits,
        &base.qcodes,
        base.scales,
        base.zeros,
        &outliers,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_layer_error;
    use crate::quant::rtn::{rtn_quantize, RtnConfig};
    use crate::util::rng::Rng;

    fn outlier_weights(rng: &mut Rng) -> Tensor {
        let mut w = Tensor::randn(&[16, 64], 1.0, rng);
        // 1% of weights are 10–20× larger.
        for _ in 0..10 {
            let i = rng.below(16);
            let j = rng.below(64);
            w.set2(i, j, 15.0 * if rng.f32() < 0.5 { -1.0 } else { 1.0 });
        }
        w
    }

    #[test]
    fn spqr_beats_rtn_on_outlier_weights() {
        let mut rng = Rng::seed_from_u64(1);
        let w = outlier_weights(&mut rng);
        let calib = CalibData::identity(64);
        let e_rtn =
            relative_layer_error(&w, &rtn_quantize(&w, RtnConfig::new(3, 16)).decode(), &calib);
        let sq = spqr_quantize(&w, &calib, SpqrConfig { bits: 3, group: 16, outlier_frac: 0.01 })
            .unwrap();
        let e_spqr = relative_layer_error(&w, &sq.decode(), &calib);
        assert!(e_spqr < e_rtn, "spqr {e_spqr} !< rtn {e_rtn}");
    }

    #[test]
    fn outlier_budget_respected_and_bits_increase() {
        let mut rng = Rng::seed_from_u64(2);
        let w = outlier_weights(&mut rng);
        let calib = CalibData::identity(64);
        let cfg = SpqrConfig { bits: 3, group: 16, outlier_frac: 0.02 };
        let sq = spqr_quantize(&w, &calib, cfg).unwrap();
        sq.validate().unwrap();
        assert_eq!(sq.n_outliers(), (16.0f64 * 64.0 * 0.02).round() as usize);
        // Hand count: 3 code bits + 32/16 group meta + 48·n_out/params
        // (16-bit value + u32 index) + 32·(d_out+1)/params CSR pointers.
        let params = 16.0 * 64.0;
        let expect = 3.0
            + 2.0
            + 48.0 * sq.n_outliers() as f64 / params
            + 32.0 * (16.0 + 1.0) / params;
        assert!((sq.avg_bits() - expect).abs() < 1e-9, "{} vs {expect}", sq.avg_bits());
    }

    #[test]
    fn more_outliers_lower_error() {
        let mut rng = Rng::seed_from_u64(3);
        let w = outlier_weights(&mut rng);
        let calib = CalibData::identity(64);
        let e1 = relative_layer_error(
            &w,
            &spqr_quantize(&w, &calib, SpqrConfig { bits: 2, group: 16, outlier_frac: 0.005 })
                .unwrap()
                .decode(),
            &calib,
        );
        let e2 = relative_layer_error(
            &w,
            &spqr_quantize(&w, &calib, SpqrConfig { bits: 2, group: 16, outlier_frac: 0.05 })
                .unwrap()
                .decode(),
            &calib,
        );
        assert!(e2 < e1, "{e2} !< {e1}");
    }

    #[test]
    fn ragged_shapes_quantize_every_column() {
        // d_in = 27 with group 16 → a full group + an 11-column ragged tail;
        // the old truncating accounting mis-handled exactly this shape.
        let mut rng = Rng::seed_from_u64(4);
        let w = Tensor::randn(&[8, 27], 1.0, &mut rng);
        let calib = CalibData::identity(27);
        let sq = spqr_quantize(&w, &calib, SpqrConfig { bits: 8, group: 16, outlier_frac: 0.01 })
            .unwrap();
        sq.validate().unwrap();
        assert_eq!(sq.n_groups(), 2);
        let e = relative_layer_error(&w, &sq.decode(), &calib);
        assert!(e < 1e-3, "tail columns left unquantized: rel_error {e}");
        // Bits accounting covers the tail group's scale/zero.
        let params = 8.0 * 27.0;
        let expect = 8.0
            + 8.0 * 2.0 * 32.0 / params
            + 48.0 * sq.n_outliers() as f64 / params
            + 32.0 * 9.0 / params;
        assert!((sq.avg_bits() - expect).abs() < 1e-9, "{} vs {expect}", sq.avg_bits());
    }
}
