//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest records, for every lowered HLO module, the
//! exact positional argument order with shapes and dtypes; the runtime
//! refuses to execute on any mismatch.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Element type of a module argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (token ids, step counters).
    I32,
}

impl Dtype {
    /// Parse the manifest's dtype string (`f32` / `i32`).
    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unsupported dtype '{other}'"),
        }
    }
}

/// One positional argument or result of a module.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Argument name (documentation only; order is what binds).
    pub name: String,
    /// Expected shape.
    pub shape: Vec<usize>,
    /// Expected element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count of the spec's shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape")))
                .collect::<anyhow::Result<Vec<_>>>()?,
            dtype: Dtype::parse(j.req_str("dtype")?)?,
        })
    }
}

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    /// Manifest key (`forward_nano_b2s4`, …).
    pub key: String,
    /// Path of the lowered HLO text file.
    pub path: PathBuf,
    /// Positional input specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Positional output specs.
    pub outputs: Vec<TensorSpec>,
    /// Batch size the module was lowered for, if fixed.
    pub batch: Option<usize>,
    /// Sequence length the module was lowered for, if fixed.
    pub seq: Option<usize>,
    /// Model preset the module was lowered for, if recorded.
    pub config: Option<String>,
}

/// The whole artifacts directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Every lowered module.
    pub modules: Vec<ModuleSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("manifest root must be an object"))?;
        let mut modules = Vec::new();
        for (key, m) in obj {
            let inputs = m
                .req_arr("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = m
                .req_arr("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            modules.push(ModuleSpec {
                key: key.clone(),
                path: dir.join(m.req_str("path")?),
                inputs,
                outputs,
                batch: m.get("batch").and_then(|v| v.as_usize()),
                seq: m.get("seq").and_then(|v| v.as_usize()),
                config: m.get("config").and_then(|v| v.as_str()).map(|s| s.to_string()),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), modules })
    }

    /// Look up a module by manifest key, listing known keys on a miss.
    pub fn module(&self, key: &str) -> anyhow::Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.key == key)
            .ok_or_else(|| anyhow::anyhow!("module '{key}' not in manifest (have: {:?})",
                self.modules.iter().map(|m| m.key.as_str()).collect::<Vec<_>>()))
    }

    /// Default artifacts directory (relative to the repo root / cwd).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"mod_a": {"path": "a.hlo.txt", "batch": 2, "seq": 4,
                 "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"},
                            {"name": "t", "shape": [2, 4], "dtype": "i32"}],
                 "outputs": [{"name": "y", "shape": [2], "dtype": "f32"}]}}"#,
        )
        .unwrap();
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("aqlm_manifest_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let spec = m.module("mod_a").unwrap();
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].dtype, Dtype::F32);
        assert_eq!(spec.inputs[1].dtype, Dtype::I32);
        assert_eq!(spec.inputs[0].elements(), 6);
        assert_eq!(spec.batch, Some(2));
        assert!(m.module("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dtype_parse_rejects_unknown() {
        assert!(Dtype::parse("f64").is_err());
    }
}
