//! Model-level PJRT engines.
//!
//! [`PjrtForward`] runs the L2 `{model}_fwd` artifact with the weights of a
//! native [`Model`] — the cross-engine agreement test (native Rust forward
//! vs AOT-compiled JAX forward) lives in `rust/tests/integration_runtime.rs`.
//!
//! [`PjrtTrainer`] drives the `{model}_train` artifact in a loop, holding
//! the parameter/optimizer state between steps — this is how the
//! end-to-end example trains base models "through the stack" (Rust
//! coordinator → AOT artifact → XLA), with Python long gone.

use super::artifacts::Manifest;
use super::pjrt::{CompiledModule, HostTensor, PjrtRuntime};
use crate::nn::block::Ffn;
use crate::nn::model::Model;
use crate::tensor::Tensor;

/// Flatten a model's parameters in the manifest's canonical order
/// (`embed`, per block `ln1,wq,wk,wv,wo,ln2,wg,wu,wd`, `ln_f`, `head`).
/// Quantized layers are decoded to dense (the L2 artifact takes dense
/// weights).
pub fn flatten_params(model: &Model) -> Vec<HostTensor> {
    let mut out = Vec::new();
    let push_t = |t: &Tensor, out: &mut Vec<HostTensor>| {
        out.push(HostTensor::f32(t.data().to_vec(), t.shape()));
    };
    push_t(&model.embed, &mut out);
    for b in &model.blocks {
        out.push(HostTensor::f32(b.ln1.clone(), &[b.ln1.len()]));
        for lin in [&b.attn.wq, &b.attn.wk, &b.attn.wv, &b.attn.wo] {
            push_t(&lin.weight_owned(), &mut out);
        }
        out.push(HostTensor::f32(b.ln2.clone(), &[b.ln2.len()]));
        match &b.ffn {
            Ffn::Dense(m) => {
                for lin in [&m.wg, &m.wu, &m.wd] {
                    push_t(&lin.weight_owned(), &mut out);
                }
            }
            Ffn::Moe(_) => panic!("PJRT engine supports dense-FFN presets only (nano/tiny/small)"),
        }
    }
    out.push(HostTensor::f32(model.ln_f.clone(), &[model.ln_f.len()]));
    push_t(&model.head.weight_owned(), &mut out);
    out
}

/// Write flattened parameters (same order) back into a model.
pub fn unflatten_params(model: &mut Model, params: &[HostTensor]) -> anyhow::Result<()> {
    let mut it = params.iter();
    let mut take_t = |shape_check: &[usize]| -> anyhow::Result<Tensor> {
        let h = it.next().ok_or_else(|| anyhow::anyhow!("param list too short"))?;
        anyhow::ensure!(h.shape() == shape_check, "shape mismatch: {:?} vs {:?}", h.shape(), shape_check);
        Ok(Tensor::from_vec(shape_check, h.as_f32()?.to_vec()))
    };
    model.embed = take_t(model.embed.shape())?;
    let n_blocks = model.blocks.len();
    for bi in 0..n_blocks {
        let d = model.cfg.d_model;
        let ln1 = take_t(&[d])?;
        model.blocks[bi].ln1 = ln1.into_vec();
        for name in ["wq", "wk", "wv", "wo"] {
            let shape = match name {
                "wq" | "wo" => [d, d],
                _ => [model.cfg.n_kv_heads * model.cfg.head_dim(), d],
            };
            let t = take_t(&shape)?;
            let lin = match name {
                "wq" => &mut model.blocks[bi].attn.wq,
                "wk" => &mut model.blocks[bi].attn.wk,
                "wv" => &mut model.blocks[bi].attn.wv,
                _ => &mut model.blocks[bi].attn.wo,
            };
            *lin = crate::nn::linear::Linear::dense(t);
        }
        let ln2 = take_t(&[d])?;
        model.blocks[bi].ln2 = ln2.into_vec();
        let ff = model.cfg.d_ff;
        match &mut model.blocks[bi].ffn {
            Ffn::Dense(m) => {
                m.wg = crate::nn::linear::Linear::dense(take_t(&[ff, d])?);
                m.wu = crate::nn::linear::Linear::dense(take_t(&[ff, d])?);
                m.wd = crate::nn::linear::Linear::dense(take_t(&[d, ff])?);
            }
            Ffn::Moe(_) => anyhow::bail!("PJRT engine supports dense-FFN presets only"),
        }
    }
    let d = model.cfg.d_model;
    model.ln_f = take_t(&[d])?.into_vec();
    model.head = crate::nn::linear::Linear::dense(take_t(&[model.cfg.vocab_size, d])?);
    Ok(())
}

/// PJRT forward engine (logits).
pub struct PjrtForward {
    module: CompiledModule,
    /// Batch size the module was lowered for.
    pub batch: usize,
    /// Sequence length the module was lowered for.
    pub seq: usize,
    vocab: usize,
}

impl PjrtForward {
    /// Compile the `{model_name}_fwd` artifact.
    pub fn load(rt: &PjrtRuntime, manifest: &Manifest, model_name: &str) -> anyhow::Result<PjrtForward> {
        let spec = manifest.module(&format!("{model_name}_fwd"))?;
        let batch = spec.batch.ok_or_else(|| anyhow::anyhow!("fwd module missing batch"))?;
        let seq = spec.seq.ok_or_else(|| anyhow::anyhow!("fwd module missing seq"))?;
        let vocab = spec.outputs[0].shape[2];
        Ok(PjrtForward { module: rt.compile(spec)?, batch, seq, vocab })
    }

    /// Run the artifact with `model`'s weights. `tokens` is [batch·seq];
    /// returns logits [batch·seq, vocab].
    pub fn logits(&self, model: &Model, tokens: &[u32]) -> anyhow::Result<Tensor> {
        anyhow::ensure!(tokens.len() == self.batch * self.seq, "token count mismatch");
        let mut inputs = flatten_params(model);
        inputs.push(HostTensor::i32(
            tokens.iter().map(|&t| t as i32).collect(),
            &[self.batch, self.seq],
        ));
        let outputs = self.module.run(&inputs)?;
        let logits = outputs[0].as_f32()?.to_vec();
        Ok(Tensor::from_vec(&[self.batch * self.seq, self.vocab], logits))
    }
}

/// PJRT training engine: owns params + Adam state across steps.
pub struct PjrtTrainer {
    module: CompiledModule,
    /// Current parameters, manifest order.
    state_params: Vec<HostTensor>,
    state_m: Vec<HostTensor>,
    state_v: Vec<HostTensor>,
    step: i32,
    /// Batch size the module was lowered for.
    pub batch: usize,
    /// Sequence length the module was lowered for.
    pub seq: usize,
}

impl PjrtTrainer {
    /// Compile the `{model_name}_train` artifact and seed the optimizer
    /// state from `init`'s parameters.
    pub fn new(
        rt: &PjrtRuntime,
        manifest: &Manifest,
        model_name: &str,
        init: &Model,
    ) -> anyhow::Result<PjrtTrainer> {
        let spec = manifest.module(&format!("{model_name}_train"))?;
        let batch = spec.batch.ok_or_else(|| anyhow::anyhow!("train module missing batch"))?;
        let seq = spec.seq.ok_or_else(|| anyhow::anyhow!("train module missing seq"))?;
        let state_params = flatten_params(init);
        let zeros: Vec<HostTensor> = state_params
            .iter()
            .map(|t| HostTensor::f32(vec![0.0; t.as_f32().unwrap().len()], t.shape()))
            .collect();
        Ok(PjrtTrainer {
            module: rt.compile(spec)?,
            state_m: zeros.clone(),
            state_v: zeros,
            state_params,
            step: 0,
            batch,
            seq,
        })
    }

    /// One Adam step on a token batch. Returns the loss.
    pub fn step(&mut self, tokens: &[u32], targets: &[u32]) -> anyhow::Result<f64> {
        anyhow::ensure!(tokens.len() == self.batch * self.seq);
        let mut inputs = Vec::with_capacity(self.state_params.len() * 3 + 3);
        inputs.extend(self.state_params.iter().cloned());
        inputs.extend(self.state_m.iter().cloned());
        inputs.extend(self.state_v.iter().cloned());
        inputs.push(HostTensor::scalar_i32(self.step));
        inputs.push(HostTensor::i32(
            tokens.iter().map(|&t| t as i32).collect(),
            &[self.batch, self.seq],
        ));
        inputs.push(HostTensor::i32(
            targets.iter().map(|&t| t as i32).collect(),
            &[self.batch, self.seq],
        ));
        let mut outputs = self.module.run(&inputs)?;
        let loss = outputs[0].as_f32()?[0] as f64;
        let n = self.state_params.len();
        // outputs: [loss, params.., m.., v..]
        let rest: Vec<HostTensor> = outputs.drain(1..).collect();
        self.state_params = rest[0..n].to_vec();
        self.state_m = rest[n..2 * n].to_vec();
        self.state_v = rest[2 * n..3 * n].to_vec();
        self.step += 1;
        Ok(loss)
    }

    /// Write the trained parameters back into a native model.
    pub fn export_into(&self, model: &mut Model) -> anyhow::Result<()> {
        unflatten_params(model, &self.state_params)
    }

    /// Number of optimizer steps taken so far.
    pub fn steps_taken(&self) -> i32 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config::ModelConfig;
    use crate::util::rng::Rng;

    #[test]
    fn flatten_unflatten_roundtrip() {
        let mut cfg = ModelConfig::nano();
        cfg.vocab_size = 32;
        let mut rng = Rng::seed_from_u64(1);
        let mut m = Model::init(&cfg, &mut rng);
        let flat = flatten_params(&m);
        // 1 embed + 2 blocks × 9 + ln_f + head
        assert_eq!(flat.len(), 1 + cfg.n_layers * 9 + 2);
        let mut m2 = Model::init(&cfg, &mut Rng::seed_from_u64(99));
        unflatten_params(&mut m2, &flat).unwrap();
        let tokens: Vec<u32> = vec![1, 2, 3, 4];
        let (l1, _) = m.forward_logits(&tokens, 1, 4, false);
        let (l2, _) = m2.forward_logits(&tokens, 1, 4, false);
        assert!(l1.allclose(&l2, 1e-6));
    }

    #[test]
    fn unflatten_rejects_wrong_shapes() {
        let mut cfg = ModelConfig::nano();
        cfg.vocab_size = 32;
        let mut rng = Rng::seed_from_u64(2);
        let m = Model::init(&cfg, &mut rng);
        let mut flat = flatten_params(&m);
        flat[0] = HostTensor::f32(vec![0.0; 4], &[2, 2]);
        let mut m2 = m.clone();
        assert!(unflatten_params(&mut m2, &flat).is_err());
    }
}
