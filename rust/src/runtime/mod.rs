//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client behind
//! the `xla` crate. This is the bridge between the build-time Python layers
//! (L1 Pallas kernel, L2 JAX model) and the run-time Rust coordinator —
//! after `make artifacts`, Python is never needed again.
//!
//! - [`artifacts`] — the JSON manifest (argument order / shapes / dtypes).
//! - [`pjrt`] — client wrapper, compiled-module cache, host↔device tensors.
//! - [`engine`] — model-level engines: PJRT forward (logits) and the
//!   state-looped PJRT trainer that drives `nano_train.hlo.txt`.
//! - [`store`] — tiered artifact store: seek-read access to indexed
//!   checkpoints, lazy per-layer model loading, and the LRU-evicted
//!   multi-tenant model registry behind `aqlm serve --models`.

pub mod artifacts;
pub mod pjrt;
pub mod engine;
pub mod store;
