//! Thin wrapper over the `xla` crate's PJRT CPU client: compile HLO-text
//! artifacts once, execute them with shape-checked host tensors.
//!
//! HLO *text* is the interchange format (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use super::artifacts::{Dtype, ModuleSpec};
use anyhow::Context;

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// f32 data + shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + shape.
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// f32 tensor (panics on shape/length mismatch).
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    /// i32 tensor (panics on shape/length mismatch).
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    /// Rank-0 i32 scalar.
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    /// The tensor's element type.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    /// Borrow the f32 data (errors for i32 tensors).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(d, _) => xla::Literal::vec1(d),
            HostTensor::I32(d, _) => xla::Literal::vec1(d),
        };
        Ok(lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))?)
    }

    fn from_literal(lit: &xla::Literal, spec: &crate::runtime::artifacts::TensorSpec) -> anyhow::Result<HostTensor> {
        match spec.dtype {
            Dtype::F32 => Ok(HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))?,
                spec.shape.clone(),
            )),
            Dtype::I32 => Ok(HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("literal to i32: {e:?}"))?,
                spec.shape.clone(),
            )),
        }
    }
}

/// PJRT CPU runtime.
pub struct PjrtRuntime {
    /// The underlying PJRT client.
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> anyhow::Result<PjrtRuntime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    /// Compile an HLO-text artifact.
    pub fn compile(&self, spec: &ModuleSpec) -> anyhow::Result<CompiledModule> {
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", spec.path.display()))
            .with_context(|| "did you run `make artifacts`?")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.key))?;
        Ok(CompiledModule { exe, spec: spec.clone() })
    }
}

/// A compiled artifact ready to execute.
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    /// The manifest spec the module was compiled from.
    pub spec: ModuleSpec,
}

impl CompiledModule {
    /// Execute with shape/dtype checking against the manifest. Outputs are
    /// returned in manifest order (AOT lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "module {}: {} inputs given, {} expected",
            self.spec.key,
            inputs.len(),
            self.spec.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (given, want) in inputs.iter().zip(&self.spec.inputs) {
            anyhow::ensure!(
                given.shape() == &want.shape[..] && given.dtype() == want.dtype,
                "module {}: arg '{}' expects {:?} {:?}, got {:?} {:?}",
                self.spec.key,
                want.name,
                want.dtype,
                want.shape,
                given.dtype(),
                given.shape()
            );
            literals.push(given.to_literal()?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.key))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e:?}", self.spec.key))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result tuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "module {}: {} outputs, manifest says {}",
            self.spec.key,
            parts.len(),
            self.spec.outputs.len()
        );
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes_and_dtypes() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.as_f32().unwrap().len(), 4);
        let i = HostTensor::scalar_i32(7);
        assert_eq!(i.shape(), &[] as &[usize]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_shape_mismatch_panics() {
        HostTensor::f32(vec![1.0; 3], &[2, 2]);
    }

    // Full PJRT execution is covered by rust/tests/integration_runtime.rs
    // (requires artifacts/ to exist).
}
