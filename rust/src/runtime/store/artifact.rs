//! Seek-read access to one indexed checkpoint file.
//!
//! [`ArtifactFile::open`] reads and validates only the header (magic,
//! header length, JSON with the section index); every tensor section stays
//! on disk until explicitly read. [`ArtifactFile::read_section`] seeks to
//! one section, reads exactly its bytes, and verifies its crc32 — the unit
//! of IO for the lazy tiers above this one.

use crate::nn::config::ModelConfig;
use crate::nn::linear::Linear;
use crate::nn::model::{config_from_json, layer_bits_from_header};
use crate::nn::section;
use crate::tensor::Tensor;
use crate::util::crc::crc32;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

/// One entry of the section index: where a tensor's bytes live and how to
/// verify them.
#[derive(Debug, Clone)]
struct SectionEntry {
    /// Full metadata object from the header (kind, geometry, ...).
    meta: Json,
    /// Byte offset inside the blob (relative to `data_start`).
    offset: u64,
    /// Section byte length.
    len: usize,
    /// Stored crc32 of the section bytes, when the header carries one.
    crc: Option<u32>,
}

/// An open indexed checkpoint: validated header in memory, tensor sections
/// on disk, any single section readable with one seek.
pub struct ArtifactFile {
    file: File,
    cfg: ModelConfig,
    quant_policy: Option<String>,
    layer_bits: HashMap<String, f64>,
    sections: BTreeMap<String, SectionEntry>,
    /// File offset where the blob starts (16 + header length).
    data_start: u64,
    /// Total bytes this handle has read so far (header included).
    bytes_read: u64,
}

impl ArtifactFile {
    /// Read just the format identifier of a checkpoint (magic + header).
    ///
    /// The registry uses this to dispatch: `aqlm-ckpt-v2` opens lazily via
    /// [`ArtifactFile::open`], legacy `aqlm-ckpt-v1` (no section index)
    /// falls back to the eager [`crate::nn::model::Model::load`].
    pub fn peek_format(path: &Path) -> anyhow::Result<String> {
        let (header, _, _) = read_header(path)?;
        Ok(header.req_str("format")?.to_string())
    }

    /// Open a checkpoint and validate its header and section index.
    ///
    /// Reads **only** the header: `bytes_read()` right after open equals
    /// `header_bytes()`. Fails with distinct errors on truncated files,
    /// bad magic, a missing section index (v1 files), and out-of-bounds
    /// section offsets.
    pub fn open(path: &Path) -> anyhow::Result<ArtifactFile> {
        let (header, file, data_start) = read_header(path)?;
        let format = header.req_str("format")?;
        anyhow::ensure!(
            format != section::FORMAT_V1,
            "checkpoint has no section index (format '{format}'); \
             use the eager Model::load path"
        );
        anyhow::ensure!(format == section::FORMAT_V2, "unsupported checkpoint format '{format}'");
        let cfg = config_from_json(
            header.get("config").ok_or_else(|| anyhow::anyhow!("no config"))?,
        )?;
        let quant_policy = header.get("policy").and_then(|p| p.as_str()).map(str::to_string);
        let layer_bits = layer_bits_from_header(&header)?;
        let blob_len = file.metadata()?.len().saturating_sub(data_start);
        let mut sections = BTreeMap::new();
        for t in header.req_arr("tensors")? {
            let name = t.req_str("name")?.to_string();
            let offset = t.req_usize("offset")? as u64;
            let len = t.req_usize("len")?;
            anyhow::ensure!(
                offset.checked_add(len as u64).is_some_and(|end| end <= blob_len),
                "section '{name}' out of bounds: offset {offset} + len {len} exceeds blob \
                 of {blob_len} bytes (truncated or corrupted checkpoint)"
            );
            let crc = t.get("crc32").and_then(Json::as_usize).map(|c| c as u32);
            sections.insert(name, SectionEntry { meta: t.clone(), offset, len, crc });
        }
        Ok(ArtifactFile {
            file,
            cfg,
            quant_policy,
            layer_bits,
            sections,
            data_start,
            bytes_read: data_start,
        })
    }

    /// Architecture config parsed from the header.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Quantization policy string from the header, if recorded.
    pub fn quant_policy(&self) -> Option<&str> {
        self.quant_policy.as_deref()
    }

    /// Per-layer bits table from the header.
    pub fn layer_bits(&self) -> &HashMap<String, f64> {
        &self.layer_bits
    }

    /// Names of all tensor sections, in index order.
    pub fn section_names(&self) -> Vec<String> {
        self.sections.keys().cloned().collect()
    }

    /// Byte length of one section, if it exists.
    pub fn section_len(&self, name: &str) -> Option<usize> {
        self.sections.get(name).map(|e| e.len)
    }

    /// Sum of all section byte lengths (the full blob).
    pub fn total_section_bytes(&self) -> u64 {
        self.sections.values().map(|e| e.len as u64).sum()
    }

    /// Size of the file prefix read at open: magic + header length word +
    /// JSON header.
    pub fn header_bytes(&self) -> u64 {
        self.data_start
    }

    /// Total bytes read through this handle so far (header included) —
    /// the observable IO cost of laziness.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    /// Seek-read one section's raw bytes and verify its crc32.
    pub fn read_section(&mut self, name: &str) -> anyhow::Result<Vec<u8>> {
        let entry = self
            .sections
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))?;
        let (offset, len, crc) = (entry.offset, entry.len, entry.crc);
        self.file.seek(SeekFrom::Start(self.data_start + offset))?;
        let mut buf = vec![0u8; len];
        self.file.read_exact(&mut buf).map_err(|e| {
            anyhow::anyhow!("section '{name}' truncated on disk ({len} bytes at {offset}): {e}")
        })?;
        if let Some(want) = crc {
            let got = crc32(&buf);
            anyhow::ensure!(
                got == want,
                "crc mismatch in section '{name}': stored {want:#010x}, computed {got:#010x}"
            );
        }
        self.bytes_read += len as u64;
        Ok(buf)
    }

    /// Read and decode one dense tensor section.
    pub fn read_dense(&mut self, name: &str) -> anyhow::Result<Tensor> {
        let bytes = self.read_section(name)?;
        section::decode_dense(&self.sections[name].meta, &bytes)
    }

    /// Read and decode one linear-layer section in its packed storage kind.
    pub fn read_linear(&mut self, name: &str) -> anyhow::Result<Linear> {
        let bytes = self.read_section(name)?;
        section::decode_linear(&self.sections[name].meta, &bytes)
    }
}

impl std::fmt::Debug for ArtifactFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactFile")
            .field("sections", &self.sections.len())
            .field("data_start", &self.data_start)
            .field("bytes_read", &self.bytes_read)
            .finish()
    }
}

/// Open `path`, validate magic and header length, and parse the JSON
/// header. Returns the header, the open file (positioned arbitrarily), and
/// the blob start offset.
fn read_header(path: &Path) -> anyhow::Result<(Json, File, u64)> {
    let mut file = File::open(path)?;
    let file_len = file.metadata()?.len();
    anyhow::ensure!(
        file_len >= 16,
        "truncated checkpoint: {file_len} bytes is too short for magic + header length"
    );
    let mut prefix = [0u8; 16];
    file.read_exact(&mut prefix)?;
    anyhow::ensure!(&prefix[..8] == section::MAGIC, "bad checkpoint magic");
    let hlen = u64::from_le_bytes(prefix[8..16].try_into().expect("8 bytes"));
    anyhow::ensure!(
        hlen.checked_add(16).is_some_and(|end| end <= file_len),
        "truncated checkpoint: header claims {hlen} bytes, file holds {}",
        file_len - 16
    );
    let mut hbytes = vec![0u8; hlen as usize];
    file.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    Ok((header, file, 16 + hlen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config::ModelConfig;
    use crate::nn::model::Model;
    use crate::util::rng::Rng;

    fn tiny_ckpt(tag: &str, seed: u64) -> (Model, std::path::PathBuf) {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 16;
        cfg.n_heads = 2;
        cfg.n_kv_heads = 2;
        cfg.d_ff = 24;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        cfg.n_layers = 2;
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = Model::init(&cfg, &mut rng);
        let q = crate::kernels::format::random_weight(
            16,
            16,
            crate::kernels::format::AqlmShape::new(2, 4, 4),
            &mut rng,
        );
        m.blocks[0].attn.wq = Linear::aqlm(q);
        let path = std::env::temp_dir().join(format!("aqlm_test_artifact_{tag}.bin"));
        m.save(&path).unwrap();
        (m, path)
    }

    #[test]
    fn open_reads_only_the_header() {
        let (_, path) = tiny_ckpt("header_only", 31);
        let art = ArtifactFile::open(&path).unwrap();
        assert_eq!(art.bytes_read(), art.header_bytes());
        assert!(art.total_section_bytes() > 0);
        assert_eq!(
            art.header_bytes() + art.total_section_bytes(),
            std::fs::metadata(&path).unwrap().len(),
            "index must cover the whole blob"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn seek_read_decodes_single_packed_tensor() {
        let (m, path) = tiny_ckpt("seek", 32);
        let mut art = ArtifactFile::open(&path).unwrap();
        let before = art.bytes_read();
        let l = art.read_linear("b0.wq").unwrap();
        let Linear::Aqlm { q, .. } = &l else { panic!("aqlm kind lost on seek-read") };
        let Linear::Aqlm { q: q0, .. } = &m.blocks[0].attn.wq else { unreachable!() };
        assert_eq!(q.codes, q0.codes);
        assert_eq!(
            art.bytes_read() - before,
            art.section_len("b0.wq").unwrap() as u64,
            "reading one section must cost exactly that section's bytes"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn peek_format_reports_v2() {
        let (_, path) = tiny_ckpt("peek", 33);
        assert_eq!(ArtifactFile::peek_format(&path).unwrap(), section::FORMAT_V2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn open_rejects_bad_magic_and_truncation() {
        let (_, path) = tiny_ckpt("corrupt", 34);
        let raw = std::fs::read(&path).unwrap();
        let mut bad = raw.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = ArtifactFile::open(&path).unwrap_err().to_string();
        assert!(err.contains("bad checkpoint magic"), "{err}");
        // Blob cut short: the index bounds check fires at open.
        std::fs::write(&path, &raw[..raw.len() - 32]).unwrap();
        let err = ArtifactFile::open(&path).unwrap_err().to_string();
        assert!(err.contains("out of bounds"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_section_detects_crc_mismatch() {
        let (_, path) = tiny_ckpt("crcflip", 35);
        let mut raw = std::fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0x01;
        std::fs::write(&path, raw).unwrap();
        let mut art = ArtifactFile::open(&path).unwrap();
        // The flipped byte lives in the last section of the index.
        let names = art.section_names();
        let victim =
            names.iter().max_by_key(|n| art.sections[n.as_str()].offset).unwrap().clone();
        let err = art.read_section(&victim).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(path).ok();
    }
}
