//! Lazily-loaded model: header-resident immediately, weights on first touch.
//!
//! [`LazyModel::open`] materializes config, quantization policy and the
//! per-layer bits table from the checkpoint header without reading a single
//! tensor section. Each linear layer has an interior-mutability slot that
//! is filled by [`LazyModel::touch_linear`] on first use (one seek-read,
//! crc-verified, decoded to its packed kind, decode caches warmed); a
//! bytes-resident counter tracks exactly which sections are in memory.
//! [`LazyModel::warm_model`] forces full residency by assembling an eager
//! [`Model`] through the same shared constructor the checkpoint loader
//! uses.

use super::artifact::ArtifactFile;
use crate::nn::config::ModelConfig;
use crate::nn::linear::Linear;
use crate::nn::model::{assemble_model, Model};
use crate::util::sync;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// A model whose weights live on disk until touched.
pub struct LazyModel {
    /// The underlying indexed checkpoint. All IO goes through this lock;
    /// slot reads (the common case once resident) never take it.
    file: Mutex<ArtifactFile>,
    cfg: ModelConfig,
    quant_policy: Option<String>,
    layer_bits: HashMap<String, f64>,
    /// One slot per tensor section. `None` = not resident.
    slots: BTreeMap<String, Slot>,
    /// Sum of the section byte lengths currently held in slots.
    bytes_resident: AtomicU64,
}

/// Residency slot for one tensor section.
///
/// Lock order is always slot → file; [`LazyModel::evict_cold`] touches only
/// slot locks, so it can never deadlock against a concurrent
/// [`LazyModel::touch_linear`].
struct Slot {
    /// Section byte length (copied from the index at open, so eviction
    /// accounting never needs the file lock).
    len: u64,
    /// The decoded layer, once touched.
    cell: RwLock<Option<Arc<Linear>>>,
}

impl LazyModel {
    /// Open a checkpoint lazily: reads only the header (config / policy /
    /// bits table / section index). `bytes_read()` afterwards equals
    /// `header_bytes()`.
    pub fn open(path: &Path) -> anyhow::Result<LazyModel> {
        let file = ArtifactFile::open(path)?;
        let cfg = file.config().clone();
        let quant_policy = file.quant_policy().map(str::to_string);
        let layer_bits = file.layer_bits().clone();
        let slots = file
            .section_names()
            .into_iter()
            .map(|name| {
                let len = file.section_len(&name).unwrap_or(0) as u64;
                (name, Slot { len, cell: RwLock::new(None) })
            })
            .collect();
        Ok(LazyModel {
            file: Mutex::new(file),
            cfg,
            quant_policy,
            layer_bits,
            slots,
            bytes_resident: AtomicU64::new(0),
        })
    }

    /// Architecture config (materialized at open).
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Quantization policy string (materialized at open).
    pub fn quant_policy(&self) -> Option<&str> {
        self.quant_policy.as_deref()
    }

    /// Per-layer bits table (materialized at open).
    pub fn layer_bits(&self) -> &HashMap<String, f64> {
        &self.layer_bits
    }

    /// Bytes of tensor sections currently resident in slots.
    pub fn bytes_resident(&self) -> u64 {
        self.bytes_resident.load(Ordering::Relaxed)
    }

    /// Total bytes read from disk so far (header included).
    pub fn bytes_read(&self) -> u64 {
        sync::lock_recover(&self.file).bytes_read()
    }

    /// Size of the header prefix read at open.
    pub fn header_bytes(&self) -> u64 {
        sync::lock_recover(&self.file).header_bytes()
    }

    /// Sum of all section byte lengths (full-residency cost).
    pub fn total_section_bytes(&self) -> u64 {
        sync::lock_recover(&self.file).total_section_bytes()
    }

    /// Fetch one linear layer, reading and decoding its section on first
    /// touch. Subsequent touches return the cached `Arc` without IO. The
    /// returned layer has its decode caches warmed, so it is immediately
    /// usable on the `&self` decode paths.
    pub fn touch_linear(&self, name: &str) -> anyhow::Result<Arc<Linear>> {
        let slot = self
            .slots
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))?;
        if let Some(l) = sync::read_recover(&slot.cell).as_ref() {
            return Ok(Arc::clone(l));
        }
        let mut guard = sync::write_recover(&slot.cell);
        // Double-checked: another thread may have filled the slot while we
        // waited for the write lock.
        if let Some(l) = guard.as_ref() {
            return Ok(Arc::clone(l));
        }
        let mut linear = sync::lock_recover(&self.file).read_linear(name)?;
        linear.warm_decode();
        let arc = Arc::new(linear);
        *guard = Some(Arc::clone(&arc));
        self.bytes_resident.fetch_add(slot.len, Ordering::Relaxed);
        Ok(arc)
    }

    /// Drop every resident slot that no caller still holds
    /// (`Arc::strong_count == 1`). Returns the number of bytes freed.
    pub fn evict_cold(&self) -> u64 {
        let mut freed = 0u64;
        for slot in self.slots.values() {
            let mut guard = sync::write_recover(&slot.cell);
            if let Some(arc) = guard.as_ref() {
                if Arc::strong_count(arc) == 1 {
                    *guard = None;
                    freed += slot.len;
                }
            }
        }
        self.bytes_resident.fetch_sub(freed, Ordering::Relaxed);
        freed
    }

    /// Force full residency: read every section and assemble an eager
    /// [`Model`] (decode caches not yet warmed — callers that serve from it
    /// should `warm_decode()` it). Goes through the same
    /// [`assemble_model`] walk as [`Model::load`], so lazy and eager
    /// construction can never drift apart.
    pub fn warm_model(&self) -> anyhow::Result<Model> {
        let mut get_dense = |name: &str| sync::lock_recover(&self.file).read_dense(name);
        let mut get_linear =
            |name: &str| sync::lock_recover(&self.file).read_linear(name);
        assemble_model(
            self.cfg.clone(),
            self.layer_bits.clone(),
            self.quant_policy.clone(),
            &mut get_dense,
            &mut get_linear,
        )
    }
}

impl std::fmt::Debug for LazyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LazyModel")
            .field("slots", &self.slots.len())
            .field("bytes_resident", &self.bytes_resident())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_ckpt(tag: &str, seed: u64) -> (Model, std::path::PathBuf) {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 16;
        cfg.n_heads = 2;
        cfg.n_kv_heads = 2;
        cfg.d_ff = 24;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        cfg.n_layers = 2;
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = Model::init(&cfg, &mut rng);
        let q = crate::kernels::format::random_weight(
            16,
            16,
            crate::kernels::format::AqlmShape::new(2, 4, 4),
            &mut rng,
        );
        m.blocks[0].attn.wq = Linear::aqlm(q);
        let path = std::env::temp_dir().join(format!("aqlm_test_lazy_{tag}.bin"));
        m.save(&path).unwrap();
        (m, path)
    }

    #[test]
    fn lazy_open_reads_header_only_and_touch_reads_one_section() {
        // The byte-accounting contract of the tiered store: opening costs
        // the header; touching layer X costs exactly X's section bytes.
        let (_, path) = tiny_ckpt("accounting", 41);
        let lm = LazyModel::open(&path).unwrap();
        assert_eq!(lm.bytes_read(), lm.header_bytes(), "open must not read any section");
        assert_eq!(lm.bytes_resident(), 0);

        let wq_len = lm.slots["b0.wq"].len;
        let l = lm.touch_linear("b0.wq").unwrap();
        assert!(l.is_quantized());
        assert_eq!(lm.bytes_read(), lm.header_bytes() + wq_len);
        assert_eq!(lm.bytes_resident(), wq_len);

        // Second touch: cache hit, zero additional IO.
        let _l2 = lm.touch_linear("b0.wq").unwrap();
        assert_eq!(lm.bytes_read(), lm.header_bytes() + wq_len);
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // assembles two full models and compares bitwise — minutes under miri
    fn warm_model_matches_eager_load_bitexact() {
        let (mut m, path) = tiny_ckpt("warm", 42);
        let lm = LazyModel::open(&path).unwrap();
        let mut warm = lm.warm_model().unwrap();
        let tokens: Vec<u32> = vec![5, 3, 8];
        let (l1, _) = m.forward_logits(&tokens, 1, 3, false);
        let (l2, _) = warm.forward_logits(&tokens, 1, 3, false);
        assert!(l1.allclose(&l2, 0.0), "lazy warm_model drifted from the saved weights");
        assert!(lm.bytes_read() >= lm.header_bytes() + lm.total_section_bytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn evict_cold_frees_unheld_slots_but_keeps_pinned_ones() {
        let (_, path) = tiny_ckpt("evict", 43);
        let lm = LazyModel::open(&path).unwrap();
        let pinned = lm.touch_linear("b0.wq").unwrap();
        lm.touch_linear("b0.wk").unwrap(); // dropped immediately → cold
        let resident = lm.bytes_resident();
        let freed = lm.evict_cold();
        assert!(freed > 0, "the cold wk slot must be freed");
        assert_eq!(lm.bytes_resident(), resident - freed);
        assert!(lm.bytes_resident() > 0, "the pinned wq slot must survive");
        drop(pinned);
        lm.evict_cold();
        assert_eq!(lm.bytes_resident(), 0);
        std::fs::remove_file(path).ok();
    }
}
