//! Tiered artifact store: from bytes on disk to models in service.
//!
//! Three tiers, each built on the one below:
//!
//! 1. [`ArtifactFile`] — opens an indexed (`aqlm-ckpt-v2`) checkpoint,
//!    validates the header, and **seek-reads single tensor sections** with
//!    per-section crc verification. Opening touches only the header; a
//!    `bytes_read` counter makes the IO cost observable.
//! 2. [`LazyModel`] — a model whose config / policy / bits table are
//!    materialized at open but whose per-linear weights are read on first
//!    touch (interior-mutability slot per layer, bytes-resident counter).
//!    `warm_model()` forces full residency and yields an eagerly usable
//!    [`crate::nn::model::Model`].
//! 3. [`ModelRegistry`] — a byte-budgeted LRU cache of warm models keyed by
//!    model id. `Arc<Model>` handles held by in-flight requests pin their
//!    model; cold models (and cold lazy layers) are evicted under pressure.
//!    This is what `aqlm serve --models name=path,...` serves from.
//!
//! See `docs/store.md` for the format layout, the residency accounting
//! rules, and a multi-model serving walkthrough.

pub mod artifact;
pub mod lazy;
pub mod registry;

pub use artifact::ArtifactFile;
pub use lazy::LazyModel;
pub use registry::{ModelRegistry, StoreStats};
