//! Byte-budgeted, LRU-evicted registry of warm models for multi-tenant
//! serving.
//!
//! Each registered model id maps to a checkpoint path. [`ModelRegistry::acquire`]
//! returns an `Arc<Model>` handle, loading the model on first use (indexed
//! `aqlm-ckpt-v2` checkpoints open lazily through [`LazyModel`]; legacy v1
//! files fall back to the eager [`Model::load`]). Loading happens under the
//! registry lock, so concurrent workers resolving the same cold model load
//! it **exactly once** — later arrivals find it warm.
//!
//! When resident bytes exceed the budget (`aqlm serve --store-budget-mb`),
//! eviction runs coldest-first over models whose handles are no longer
//! held: a worker holding the `Arc<Model>` pins it (`Arc::strong_count`
//! \> 1), so models serving in-flight requests are never evicted. Cold
//! lazy layer slots are freed before whole warm models are dropped. If
//! everything resident is pinned, the registry runs over budget rather
//! than stall — the budget is a target, not a hard allocation cap.

use super::artifact::ArtifactFile;
use super::lazy::LazyModel;
use crate::kernels::config::KernelConfig;
use crate::nn::model::Model;
use crate::nn::section;
use crate::util::sync;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Counters and residency snapshot of a [`ModelRegistry`].
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// `acquire` calls answered by an already-warm model.
    pub hits: u64,
    /// `acquire` calls that had to load the model from disk.
    pub misses: u64,
    /// Whole warm models dropped under byte pressure.
    pub evictions: u64,
    /// Checkpoint loads performed (equals `misses`; kept separate so the
    /// exactly-once property is directly observable).
    pub loads: u64,
    /// Bytes currently resident across all warm models and lazy slots.
    pub bytes_resident: u64,
    /// Byte budget the registry evicts toward (0 = unbounded).
    pub budget_bytes: u64,
    /// Per-model request counts, in registration order: `(id, requests)`.
    pub per_model: Vec<(String, u64)>,
}

struct Entry {
    path: PathBuf,
    /// Fully-resident model, when loaded. Dropping this is eviction.
    warm: Option<Arc<Model>>,
    /// Bytes the warm model accounts for (header + all section bytes).
    warm_bytes: u64,
    /// Lazy handle kept alongside the warm model for v2 checkpoints, so
    /// diagnostics and layer-level eviction remain available.
    lazy: Option<Arc<LazyModel>>,
    /// Logical-clock tick of the most recent acquire (LRU key).
    last_used: u64,
    /// Total acquires routed to this model.
    requests: u64,
}

struct Inner {
    entries: BTreeMap<String, Entry>,
    /// Monotonic logical clock; bumped per acquire. Cheaper and more
    /// deterministic than wall-clock timestamps for LRU ordering.
    clock: u64,
    budget_bytes: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    loads: u64,
    /// Kernel knobs stamped onto every model loaded through [`ModelRegistry::acquire`]
    /// (before warm-up). Bit-identical output for any setting.
    kernel: KernelConfig,
}

impl Inner {
    fn bytes_resident(&self) -> u64 {
        self.entries
            .values()
            .map(|e| {
                let warm = if e.warm.is_some() { e.warm_bytes } else { 0 };
                let lazy = e.lazy.as_ref().map_or(0, |l| l.bytes_resident());
                warm + lazy
            })
            .sum()
    }

    /// Evict until resident bytes fit the budget or nothing evictable
    /// remains. Cold lazy slots go first, then whole warm models in LRU
    /// order — skipping any model whose `Arc` is still held elsewhere.
    fn evict_under_pressure(&mut self) {
        if self.budget_bytes == 0 {
            return;
        }
        if self.bytes_resident() > self.budget_bytes {
            for e in self.entries.values() {
                if let Some(lazy) = &e.lazy {
                    lazy.evict_cold();
                }
            }
        }
        while self.bytes_resident() > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| {
                    e.warm.as_ref().is_some_and(|arc| Arc::strong_count(arc) == 1)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(name, _)| name.clone());
            let Some(name) = victim else { break };
            let e = self.entries.get_mut(&name).expect("victim exists");
            e.warm = None;
            if let Some(lazy) = &e.lazy {
                lazy.evict_cold();
            }
            self.evictions += 1;
        }
    }
}

/// LRU-evicted, byte-budgeted cache of warm models keyed by model id.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
}

impl ModelRegistry {
    /// Convert a user-facing MiB budget to bytes without overflow: absurd
    /// budgets saturate to `u64::MAX` (effectively unbounded) instead of
    /// wrapping into a tiny budget that would evict everything.
    pub fn budget_bytes_from_mb(mb: u64) -> u64 {
        mb.saturating_mul(1024 * 1024)
    }

    /// Empty registry evicting toward `budget_bytes` (0 = unbounded).
    pub fn new(budget_bytes: u64) -> ModelRegistry {
        ModelRegistry {
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                clock: 0,
                budget_bytes,
                hits: 0,
                misses: 0,
                evictions: 0,
                loads: 0,
                kernel: KernelConfig::default(),
            }),
        }
    }

    /// Set the kernel execution knobs (threads, SIMD) applied to every model
    /// loaded by later [`Self::acquire`] calls. Already-warm models keep the
    /// config they were loaded with; output is bit-identical either way.
    pub fn set_kernel_config(&self, cfg: KernelConfig) {
        sync::lock_recover(&self.inner).kernel = cfg;
    }

    /// Register a model id → checkpoint path mapping (no IO yet).
    pub fn register(&self, name: &str, path: &Path) {
        let mut inner = sync::lock_recover(&self.inner);
        inner.entries.insert(
            name.to_string(),
            Entry {
                path: path.to_path_buf(),
                warm: None,
                warm_bytes: 0,
                lazy: None,
                last_used: 0,
                requests: 0,
            },
        );
    }

    /// Registered model ids, in sorted order.
    pub fn names(&self) -> Vec<String> {
        sync::lock_recover(&self.inner).entries.keys().cloned().collect()
    }

    /// Acquire a warm handle to `name`, loading the checkpoint on first
    /// use. The returned `Arc` pins the model against eviction for as long
    /// as the caller holds it.
    ///
    /// Loading runs under the registry lock: other acquirers of the same
    /// cold model block and then hit the warm entry, so a checkpoint is
    /// read from disk exactly once no matter how many workers race for it.
    pub fn acquire(&self, name: &str) -> anyhow::Result<Arc<Model>> {
        let mut inner = sync::lock_recover(&self.inner);
        inner.clock += 1;
        let tick = inner.clock;
        let entry = inner
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
        entry.last_used = tick;
        entry.requests += 1;
        let warm = entry.warm.as_ref().map(Arc::clone);
        let handle = match warm {
            Some(handle) => {
                inner.hits += 1;
                handle
            }
            None => {
                inner.misses += 1;
                inner.loads += 1;
                let kernel = inner.kernel;
                let entry = inner.entries.get_mut(name).expect("entry exists");
                let path = entry.path.clone();
                let (mut model, warm_bytes, lazy) =
                    if ArtifactFile::peek_format(&path)? == section::FORMAT_V2 {
                        let lazy = match entry.lazy.clone() {
                            Some(l) => l,
                            None => Arc::new(LazyModel::open(&path)?),
                        };
                        let model = lazy.warm_model()?;
                        let bytes = lazy.header_bytes() + lazy.total_section_bytes();
                        (model, bytes, Some(lazy))
                    } else {
                        // Legacy checkpoint without a section index: eager path.
                        let model = Model::load(&path)?;
                        (model, std::fs::metadata(&path)?.len(), None)
                    };
                model.kernel = kernel;
                model.warm_decode();
                let handle = Arc::new(model);
                entry.warm = Some(Arc::clone(&handle));
                entry.warm_bytes = warm_bytes;
                entry.lazy = lazy;
                handle
            }
        };
        // The caller's handle keeps its model's strong count above 1, so
        // the model being acquired can never be its own eviction victim.
        inner.evict_under_pressure();
        Ok(handle)
    }

    /// Acquire the lazy handle of an indexed checkpoint without forcing
    /// residency (fails for legacy v1 files). Useful for diagnostics and
    /// per-layer workloads.
    pub fn acquire_lazy(&self, name: &str) -> anyhow::Result<Arc<LazyModel>> {
        let mut inner = sync::lock_recover(&self.inner);
        inner.clock += 1;
        let tick = inner.clock;
        let entry = inner
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
        entry.last_used = tick;
        if let Some(lazy) = &entry.lazy {
            return Ok(Arc::clone(lazy));
        }
        let lazy = Arc::new(LazyModel::open(&entry.path)?);
        entry.lazy = Some(Arc::clone(&lazy));
        Ok(lazy)
    }

    /// Snapshot of counters and current residency.
    pub fn stats(&self) -> StoreStats {
        let inner = sync::lock_recover(&self.inner);
        StoreStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            loads: inner.loads,
            bytes_resident: inner.bytes_resident(),
            budget_bytes: inner.budget_bytes,
            per_model: inner.entries.iter().map(|(n, e)| (n.clone(), e.requests)).collect(),
        }
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("ModelRegistry")
            .field("models", &stats.per_model.len())
            .field("bytes_resident", &stats.bytes_resident)
            .field("budget_bytes", &stats.budget_bytes)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config::ModelConfig;
    use crate::util::rng::Rng;

    fn tiny_ckpt(tag: &str, seed: u64) -> std::path::PathBuf {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 16;
        cfg.n_heads = 2;
        cfg.n_kv_heads = 2;
        cfg.d_ff = 24;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        cfg.n_layers = 1;
        let mut rng = Rng::seed_from_u64(seed);
        let m = Model::init(&cfg, &mut rng);
        let path = std::env::temp_dir().join(format!("aqlm_test_registry_{tag}.bin"));
        m.save(&path).unwrap();
        path
    }

    #[test]
    fn unknown_model_is_an_error() {
        let reg = ModelRegistry::new(0);
        let err = reg.acquire("nope").unwrap_err().to_string();
        assert!(err.contains("unknown model 'nope'"), "{err}");
    }

    #[test]
    fn lru_evicts_coldest_unpinned_model_under_budget() {
        let pa = tiny_ckpt("lru_a", 51);
        let pb = tiny_ckpt("lru_b", 52);
        let one_model = std::fs::metadata(&pa).unwrap().len();
        // Budget fits one model but not two.
        let reg = ModelRegistry::new(one_model + one_model / 2);
        reg.register("a", &pa);
        reg.register("b", &pb);
        drop(reg.acquire("a").unwrap());
        drop(reg.acquire("b").unwrap()); // loading b pushes a (coldest) out
        let stats = reg.stats();
        assert_eq!(stats.loads, 2);
        assert!(stats.evictions >= 1, "{stats:?}");
        assert!(stats.bytes_resident <= stats.budget_bytes, "{stats:?}");
        // Re-acquiring a is a miss again (it was evicted), and now b goes.
        drop(reg.acquire("a").unwrap());
        assert_eq!(reg.stats().loads, 3);
        std::fs::remove_file(pa).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn pinned_model_is_never_evicted() {
        let pa = tiny_ckpt("pin_a", 53);
        let pb = tiny_ckpt("pin_b", 54);
        let reg = ModelRegistry::new(1); // absurdly tight: everything is pressure
        reg.register("a", &pa);
        reg.register("b", &pb);
        let held_a = reg.acquire("a").unwrap(); // pinned by this handle
        let _b = reg.acquire("b").unwrap();
        // a was the LRU candidate but is pinned; b is pinned by _b. Neither
        // may be evicted even though the registry is far over budget.
        assert_eq!(reg.stats().evictions, 0);
        // Prove a's weights are still live and servable.
        assert_eq!(held_a.cfg.d_model, 16);
        drop(held_a);
        // Next acquire triggers pressure handling again; now a is evictable.
        drop(reg.acquire("b").unwrap());
        assert!(reg.stats().evictions >= 1);
        std::fs::remove_file(pa).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn mb_budget_saturates_instead_of_wrapping() {
        assert_eq!(ModelRegistry::budget_bytes_from_mb(0), 0);
        assert_eq!(ModelRegistry::budget_bytes_from_mb(3), 3 * 1024 * 1024);
        // A wrapping multiply here would produce a tiny budget and evict
        // every model; saturation means "unbounded in practice".
        assert_eq!(ModelRegistry::budget_bytes_from_mb(u64::MAX / 2), u64::MAX);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // 8 threads × full checkpoint loads — minutes under miri (TSan covers it)
    fn concurrent_acquires_load_exactly_once() {
        let pa = tiny_ckpt("race", 55);
        let reg = Arc::new(ModelRegistry::new(0));
        reg.register("m", &pa);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let reg = Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                let m = reg.acquire("m").unwrap();
                assert_eq!(m.cfg.d_model, 16);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = reg.stats();
        assert_eq!(stats.loads, 1, "{stats:?}");
        assert_eq!(stats.hits + stats.misses, 8);
        assert_eq!(stats.per_model, vec![("m".to_string(), 8)]);
        std::fs::remove_file(pa).ok();
    }
}
