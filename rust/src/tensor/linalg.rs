//! Dense linear algebra on small symmetric matrices.
//!
//! GPTQ requires the inverse (Cholesky factor) of the damped Hessian
//! H = 2XXᵀ + λI; Figure 7 requires the leading principal components of a
//! learned codebook. Everything is f64 internally for stability (the
//! Hessians of tiny calibration sets are often near-singular).

use super::Tensor;
use crate::util::rng::Rng;

/// Cholesky decomposition of a symmetric positive-definite matrix
/// (f32 tensor, f64 accumulation). Returns lower-triangular L with
/// A = L Lᵀ, or an error if the matrix is not SPD.
pub fn cholesky(a: &Tensor) -> anyhow::Result<Tensor> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let ad = a.data();
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = ad[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    anyhow::bail!("cholesky: matrix not positive definite at pivot {i} (s={s})");
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(Tensor::from_vec(&[n, n], l.into_iter().map(|v| v as f32).collect()))
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let ld = l.data();
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= ld[i * n + k] as f64 * y[k];
        }
        y[i] = s / ld[i * n + i] as f64;
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Solve Lᵀ x = y for lower-triangular L (back substitution).
pub fn solve_lower_t(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let ld = l.data();
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in i + 1..n {
            s -= ld[k * n + i] as f64 * x[k];
        }
        x[i] = s / ld[i * n + i] as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

/// Solve A x = b via Cholesky for SPD A.
pub fn solve_spd(a: &Tensor, b: &[f32]) -> anyhow::Result<Vec<f32>> {
    let l = cholesky(a)?;
    Ok(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solves).
pub fn inverse_spd(a: &Tensor) -> anyhow::Result<Tensor> {
    let n = a.rows();
    let l = cholesky(a)?;
    let mut inv = Tensor::zeros(&[n, n]);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = solve_lower_t(&l, &solve_lower(&l, &e));
        for i in 0..n {
            inv.set2(i, j, col[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

/// Add λ to the diagonal in place (Hessian damping).
pub fn add_diag(a: &mut Tensor, lambda: f32) {
    let n = a.rows();
    for i in 0..n {
        let v = a.at2(i, i) + lambda;
        a.set2(i, i, v);
    }
}

/// Mean of the diagonal (used to scale GPTQ's percdamp).
pub fn diag_mean(a: &Tensor) -> f32 {
    let n = a.rows();
    (0..n).map(|i| a.at2(i, i)).sum::<f32>() / n as f32
}

/// Leading `k` principal components of the rows of `x` ([n, d]) via power
/// iteration with deflation on the covariance. Returns ([k, d] components,
/// k eigenvalues). Used for Figure 7's codebook visualization.
pub fn pca(x: &Tensor, k: usize, iters: usize, rng: &mut Rng) -> (Tensor, Vec<f32>) {
    let (n, d) = (x.rows(), x.cols());
    // Center.
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for (m, &v) in mean.iter_mut().zip(x.row(i)) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    // Covariance (d x d), f64.
    let mut cov = vec![0.0f64; d * d];
    for i in 0..n {
        let row = x.row(i);
        for a in 0..d {
            let xa = row[a] as f64 - mean[a];
            for b in 0..d {
                cov[a * d + b] += xa * (row[b] as f64 - mean[b]);
            }
        }
    }
    for c in &mut cov {
        *c /= n as f64;
    }
    let mut comps = Tensor::zeros(&[k, d]);
    let mut eigs = vec![0.0f32; k];
    for c in 0..k {
        // Power iteration.
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        normalize(&mut v);
        let mut lambda = 0.0f64;
        for _ in 0..iters {
            let mut w = vec![0.0f64; d];
            for a in 0..d {
                let mut s = 0.0;
                for b in 0..d {
                    s += cov[a * d + b] * v[b];
                }
                w[a] = s;
            }
            lambda = norm(&w);
            if lambda < 1e-30 {
                break;
            }
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / lambda;
            }
        }
        for a in 0..d {
            comps.set2(c, a, v[a] as f32);
        }
        eigs[c] = lambda as f32;
        // Deflate: cov -= λ v vᵀ
        for a in 0..d {
            for b in 0..d {
                cov[a * d + b] -= lambda * v[a] * v[b];
            }
        }
    }
    (comps, eigs)
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

/// Generate a random orthogonal matrix (QR of a Gaussian via modified
/// Gram–Schmidt). Used by the QuIP-lite baseline's incoherence rotation.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Tensor {
    let mut q = vec![vec![0.0f64; n]; n];
    for row in q.iter_mut() {
        for v in row.iter_mut() {
            *v = rng.normal();
        }
    }
    for i in 0..n {
        for j in 0..i {
            let proj: f64 = (0..n).map(|k| q[i][k] * q[j][k]).sum();
            for k in 0..n {
                q[i][k] -= proj * q[j][k];
            }
        }
        let nrm = norm(&q[i]);
        assert!(nrm > 1e-12, "degenerate Gram-Schmidt");
        for v in q[i].iter_mut() {
            *v /= nrm;
        }
    }
    let mut out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            out.set2(i, j, q[i][j] as f32);
        }
    }
    out
}

/// Deterministic "randomized Hadamard-like" orthogonal transform for
/// dimensions that are powers of two: H·diag(signs)/√n applied to a vector
/// in O(n log n). Falls back to dense random orthogonal otherwise.
pub fn hadamard_transform(x: &mut [f32], signs: &[f32]) {
    let n = x.len();
    assert!(n.is_power_of_two());
    assert_eq!(signs.len(), n);
    for (v, &s) in x.iter_mut().zip(signs) {
        *v *= s;
    }
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;

    fn spd_matrix(n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::seed_from_u64(seed);
        let a = Tensor::randn(&[n, n], 1.0, &mut rng);
        let mut h = matmul(&a, &a.transpose());
        add_diag(&mut h, 0.5);
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd_matrix(8, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul(&l, &l.transpose());
        assert!(rec.allclose(&a, 1e-3));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_accurate() {
        let a = spd_matrix(12, 2);
        let mut rng = Rng::seed_from_u64(3);
        let x_true: Vec<f32> = (0..12).map(|_| rng.normal() as f32).collect();
        let mut b = vec![0.0f32; 12];
        for i in 0..12 {
            b[i] = crate::tensor::ops::dot(a.row(i), &x_true);
        }
        let x = solve_spd(&a, &b).unwrap();
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "i={i}: {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn inverse_spd_identity() {
        let a = spd_matrix(6, 4);
        let inv = inverse_spd(&a).unwrap();
        let prod = matmul(&a, &inv);
        assert!(prod.allclose(&Tensor::eye(6), 1e-2));
    }

    #[test]
    fn pca_finds_dominant_direction() {
        let mut rng = Rng::seed_from_u64(5);
        // Points along direction (3,4)/5 with small noise.
        let dir = [0.6f32, 0.8];
        let mut data = Vec::new();
        for _ in 0..500 {
            let t = rng.normal() as f32 * 5.0;
            data.push(t * dir[0] + 0.1 * rng.normal() as f32);
            data.push(t * dir[1] + 0.1 * rng.normal() as f32);
        }
        let x = Tensor::from_vec(&[500, 2], data);
        let (comps, eigs) = pca(&x, 2, 100, &mut rng);
        let c0 = comps.row(0);
        let alignment = (c0[0] * dir[0] + c0[1] * dir[1]).abs();
        assert!(alignment > 0.99, "alignment={alignment}");
        assert!(eigs[0] > 10.0 * eigs[1]);
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::seed_from_u64(6);
        let q = random_orthogonal(16, &mut rng);
        let qtq = matmul(&q, &q.transpose());
        assert!(qtq.allclose(&Tensor::eye(16), 1e-4));
    }

    #[test]
    fn hadamard_preserves_norm() {
        let mut rng = Rng::seed_from_u64(7);
        let mut x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let signs: Vec<f32> = (0..64).map(|_| if rng.f32() < 0.5 { -1.0 } else { 1.0 }).collect();
        let before: f32 = x.iter().map(|v| v * v).sum();
        hadamard_transform(&mut x, &signs);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() / before < 1e-4);
    }

    #[test]
    fn hadamard_involution_up_to_signs() {
        // H (H x) = x when signs are all +1 (H is symmetric orthogonal).
        let mut x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let orig = x.clone();
        let signs = vec![1.0f32; 8];
        hadamard_transform(&mut x, &signs);
        hadamard_transform(&mut x, &signs);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn diag_helpers() {
        let mut a = Tensor::eye(3);
        assert_eq!(diag_mean(&a), 1.0);
        add_diag(&mut a, 2.0);
        assert_eq!(diag_mean(&a), 3.0);
    }
}
