//! Dense f32 tensor library.
//!
//! The image ships no BLAS and no ndarray, so this module is the numeric
//! substrate for the whole runtime: an owned row-major n-d [`Tensor`],
//! matrix/vector kernels in [`ops`], and the dense linear algebra
//! ([`linalg`]: Cholesky, triangular solves, power-iteration PCA) required
//! by GPTQ's Hessian inverse and Figure 7's codebook analysis.

pub mod ops;
pub mod linalg;

use crate::util::rng::Rng;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ----- constructors -----

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Constant-filled tensor of the given shape.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![value; n] }
    }

    /// Wrap an existing row-major buffer (panics on shape/length mismatch).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Gaussian init N(0, std).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// Uniform init U[lo, hi).
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_uniform(&mut t.data, lo, hi);
        t
    }

    /// Identity matrix n×n.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    // ----- shape -----

    /// Dimension sizes, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows for a 2-d tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    /// Number of cols for a 2-d tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    /// Reinterpret the same buffer under a new shape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // ----- data access -----

    /// The flat row-major buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element `(i, j)` of a 2-d tensor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Set element `(i, j)` of a 2-d tensor.
    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    /// Row `i` of a 2-d tensor as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row `i` of a 2-d tensor.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of column `j` of a 2-d tensor.
    pub fn col(&self, j: usize) -> Vec<f32> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0]).map(|i| self.at2(i, j)).collect()
    }

    // ----- elementwise -----

    /// Apply `f` to every element, consuming and returning the tensor.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Elementwise `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise `self -= other` (shapes must match).
    pub fn sub_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// Scale every element by `s`.
    pub fn scale_assign(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// self += s * other  (axpy)
    pub fn axpy(&mut self, s: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.sub_assign(other);
        out
    }

    /// Elementwise sum `self + other`.
    pub fn add(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    // ----- reductions -----

    /// Sum of all elements (f64 accumulation).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    /// Squared Frobenius norm (f64 accumulation).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius inner product ⟨self, other⟩.
    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Mean squared difference to another tensor.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }

    // ----- 2-d manipulation -----

    /// Transpose a 2-d tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..r).step_by(B) {
            for jb in (0..c).step_by(B) {
                for i in ib..(ib + B).min(r) {
                    for j in jb..(jb + B).min(c) {
                        out.data[j * r + i] = self.data[i * c + j];
                    }
                }
            }
        }
        out
    }

    /// Select a contiguous row range [start, end) of a 2-d tensor.
    pub fn rows_slice(&self, start: usize, end: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert!(start <= end && end <= self.shape[0]);
        let c = self.shape[1];
        Tensor::from_vec(&[end - start, c], self.data[start * c..end * c].to_vec())
    }

    /// Stack 2-d tensors along rows.
    pub fn vstack(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let c = parts[0].cols();
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            assert_eq!(p.cols(), c);
            rows += p.rows();
            data.extend_from_slice(p.data());
        }
        Tensor::from_vec(&[rows, c], data)
    }

    /// Approximate equality (max abs elementwise difference ≤ tol).
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= tol + tol * a.abs().max(b.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_shape() {
        Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn indexing_roundtrip() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1)[2], 5.0);
        assert_eq!(t.col(2)[1], 5.0);
    }

    #[test]
    fn transpose_correct() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at2(0, 1), 4.0);
        assert_eq!(tt.at2(2, 0), 3.0);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn transpose_blocked_large() {
        let mut rng = Rng::seed_from_u64(1);
        let t = Tensor::randn(&[67, 41], 1.0, &mut rng);
        let tt = t.transpose();
        for i in 0..67 {
            for j in 0..41 {
                assert_eq!(t.at2(i, j), tt.at2(j, i));
            }
        }
    }

    #[test]
    fn arithmetic() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::full(&[2, 2], 1.0);
        let c = a.add(&b);
        assert_eq!(c.data(), &[2., 3., 4., 5.]);
        let d = c.sub(&b);
        assert_eq!(d.data(), a.data());
        let mut e = a.clone();
        e.axpy(2.0, &b);
        assert_eq!(e.data(), &[3., 4., 5., 6.]);
    }

    #[test]
    fn reductions() {
        let a = Tensor::from_vec(&[1, 3], vec![3., 4., 0.]);
        assert_eq!(a.sum(), 7.0);
        assert_eq!(a.sq_norm(), 25.0);
        assert_eq!(a.norm(), 5.0);
        assert_eq!(a.max_abs(), 4.0);
        let b = Tensor::from_vec(&[1, 3], vec![1., 1., 1.]);
        assert_eq!(a.dot(&b), 7.0);
        assert!((a.mse(&b) - ((4.0 + 9.0 + 1.0) / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn eye_and_map() {
        let i = Tensor::eye(3);
        assert_eq!(i.sum(), 3.0);
        let j = i.map(|x| x * 2.0);
        assert_eq!(j.at2(1, 1), 2.0);
        assert_eq!(j.at2(0, 1), 0.0);
    }

    #[test]
    fn vstack_and_rows_slice() {
        let a = Tensor::from_vec(&[1, 2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let s = Tensor::vstack(&[&a, &b]);
        assert_eq!(s.shape(), &[3, 2]);
        let mid = s.rows_slice(1, 3);
        assert_eq!(mid.data(), b.data());
    }

    #[test]
    fn allclose_tolerance() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 2], vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-5));
        assert!(!a.allclose(&b, 1e-9));
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::seed_from_u64(5);
        let t = Tensor::randn(&[100, 100], 2.0, &mut rng);
        let mean = t.sum() / t.len() as f64;
        let var = t.sq_norm() / t.len() as f64 - mean * mean;
        assert!(mean.abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }
}
