//! Matrix / vector kernels over [`Tensor`].
//!
//! Hand-written "BLAS": a register-blocked GEMM (the single-core hot path of
//! the whole system), GEMV, and the neural-net elementwise primitives
//! (softmax, RMSNorm, SiLU). GEMM uses an i-k-j loop order with 4-row
//! micro-panels so the inner loop is a pure FMA stream the compiler can
//! auto-vectorize; see EXPERIMENTS.md §Perf for before/after numbers.

use super::Tensor;

/// C = A @ B  (A: [m,k], B: [k,n]).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut out);
    out
}

/// C = A @ B accumulated into pre-allocated `out` (overwrites).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(out.shape(), &[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    od.fill(0.0);
    // Micro-panel of 4 rows of A; inner j-loop is contiguous over B and C.
    let mut i = 0;
    while i + 4 <= m {
        let (a0, a1, a2, a3) = (
            &ad[i * k..(i + 1) * k],
            &ad[(i + 1) * k..(i + 2) * k],
            &ad[(i + 2) * k..(i + 3) * k],
            &ad[(i + 3) * k..(i + 4) * k],
        );
        for p in 0..k {
            let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let (c0, rest) = od[i * n..].split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, rest) = rest.split_at_mut(n);
            let c3 = &mut rest[..n];
            for j in 0..n {
                let bj = brow[j];
                c0[j] += v0 * bj;
                c1[j] += v1 * bj;
                c2[j] += v2 * bj;
                c3[j] += v3 * bj;
            }
        }
        i += 4;
    }
    while i < m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut od[i * n..(i + 1) * n];
        for p in 0..k {
            let v = arow[p];
            if v == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += v * brow[j];
            }
        }
        i += 1;
    }
}

/// C = A @ Bᵀ  (A: [m,k], B: [n,k]) — the layout of a linear layer
/// `y = x Wᵀ` with row-major W[out,in]; inner loop is a dot product of two
/// contiguous rows, which auto-vectorizes well.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_bt inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_bt_into(a, b, &mut out);
    out
}

/// C = A @ Bᵀ into pre-allocated `out` (overwrites).
pub fn matmul_bt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.rows(), a.cols());
    let n = b.rows();
    assert_eq!(out.shape(), &[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut od[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, &bd[j * k..(j + 1) * k]);
        }
    }
}

/// C = Aᵀ @ B  (A: [k,m], B: [k,n]) — gradient accumulation layout.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_at inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    // Accumulate rank-1 updates; contiguous in both B row and C row.
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for i in 0..m {
            let v = arow[i];
            if v == 0.0 {
                continue;
            }
            let crow = &mut od[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += v * brow[j];
            }
        }
    }
    out
}

/// `y = W @ x` for `W:[m,k]`, `x:[k]` — the GEMV baseline the paper's Table 5
/// compares AQLM kernels against.
pub fn gemv(w: &Tensor, x: &[f32], y: &mut [f32]) {
    let (m, k) = (w.rows(), w.cols());
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), m);
    let wd = w.data();
    for i in 0..m {
        y[i] = dot(&wd[i * k..(i + 1) * k], x);
    }
}

/// Unrolled dot product (4 accumulators to break the FP dependency chain).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// In-place row softmax of a 2-d tensor.
pub fn softmax_rows(t: &mut Tensor) {
    let (r, c) = (t.rows(), t.cols());
    let d = t.data_mut();
    for i in 0..r {
        let row = &mut d[i * c..(i + 1) * c];
        softmax_inplace(row);
    }
}

/// Numerically-stable softmax of a slice.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// log-softmax of a slice into `out`.
pub fn log_softmax(row: &[f32], out: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let lse = row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    for (o, &x) in out.iter_mut().zip(row) {
        *o = x - lse;
    }
}

/// RMSNorm (Zhang & Sennrich 2019): x * g / rms(x). Returns the rms values
/// (needed by the backward pass).
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) -> f32 {
    let n = x.len();
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64;
    let rinv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for i in 0..n {
        out[i] = x[i] * rinv * gain[i];
    }
    rinv
}

/// SiLU activation x·σ(x).
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Derivative of SiLU.
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// XXᵀ accumulation: given X with columns as samples stored as [n, d] rows
/// (each row one sample), accumulate H += Σ x xᵀ into `h` ([d, d]).
pub fn accumulate_gram(samples: &Tensor, h: &mut Tensor) {
    let (n, d) = (samples.rows(), samples.cols());
    assert_eq!(h.shape(), &[d, d]);
    let sd = samples.data();
    let hd = h.data_mut();
    for s in 0..n {
        let x = &sd[s * d..(s + 1) * d];
        for i in 0..d {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut hd[i * d..(i + 1) * d];
            for j in 0..d {
                hrow[j] += xi * x[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(p, j);
                }
                out.set2(i, j, s);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from_u64(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (8, 8, 8), (13, 9, 17), (33, 47, 29)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive_matmul(&a, &b);
            assert!(c.allclose(&r, 1e-4), "mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::seed_from_u64(2);
        let a = Tensor::randn(&[9, 16], 1.0, &mut rng);
        let b = Tensor::randn(&[11, 16], 1.0, &mut rng);
        let c = matmul_bt(&a, &b);
        let r = naive_matmul(&a, &b.transpose());
        assert!(c.allclose(&r, 1e-4));
    }

    #[test]
    fn matmul_at_matches() {
        let mut rng = Rng::seed_from_u64(3);
        let a = Tensor::randn(&[12, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[12, 5], 1.0, &mut rng);
        let c = matmul_at(&a, &b);
        let r = naive_matmul(&a.transpose(), &b);
        assert!(c.allclose(&r, 1e-4));
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::seed_from_u64(4);
        let w = Tensor::randn(&[10, 20], 1.0, &mut rng);
        let x = Tensor::randn(&[20, 1], 1.0, &mut rng);
        let mut y = vec![0.0; 10];
        gemv(&w, x.data(), &mut y);
        let r = matmul(&w, &x);
        for i in 0..10 {
            assert!((y[i] - r.data()[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..20 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b: Vec<f32> = (0..n).map(|i| (i * 2) as f32).collect();
            let expect: f32 = (0..n).map(|i| (i * i * 2) as f32).sum();
            assert_eq!(dot(&a, &b), expect, "n={n}");
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1., 0., 1.]);
        softmax_rows(&mut t);
        for i in 0..2 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(t.row(i).iter().all(|&p| p > 0.0));
        }
        // Ordering preserved.
        assert!(t.at2(0, 2) > t.at2(0, 1));
    }

    #[test]
    fn softmax_stable_with_large_values() {
        let mut row = vec![1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut row);
        assert!(row.iter().all(|p| p.is_finite()));
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let row = vec![0.5f32, -1.0, 2.0];
        let mut ls = vec![0.0; 3];
        log_softmax(&row, &mut ls);
        let sum_exp: f32 = ls.iter().map(|&v| v.exp()).sum();
        assert!((sum_exp - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_gain() {
        let x = vec![3.0f32, 4.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] - 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn silu_values_and_grad() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
        // finite-difference check of silu_grad
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let h = 1e-3;
            let fd = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((silu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn gram_accumulation() {
        let x = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let mut h = Tensor::zeros(&[2, 2]);
        accumulate_gram(&x, &mut h);
        // XtX = [[1+9, 2+12],[2+12, 4+16]]
        assert_eq!(h.data(), &[10., 14., 14., 20.]);
    }
}
