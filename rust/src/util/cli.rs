//! Tiny GNU-style command-line parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and typed
//! accessors with defaults. The `aqlm` binary, examples and bench harness
//! all parse through this.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, key→value options, and boolean flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (`aqlm <command> …`), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches (no value).
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    args.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = iter.next().unwrap();
                    args.options.insert(rest.to_string(), val);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the bare switch `--name` present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// `usize` option with a default (unparsable values fall back too).
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `u64` option with a default (unparsable values fall back too).
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `f64` option with a default (unparsable values fall back too).
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Required option with a helpful error.
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["quantize", "--model", "tiny", "--bits=2.3", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("quantize"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.f64_or("bits", 0.0), 2.3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_after_command() {
        let a = parse(&["eval", "path/a", "path/b"]);
        assert_eq!(a.positional, vec!["path/a", "path/b"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.usize_or("steps", 100), 100);
        assert_eq!(a.str_or("name", "d"), "d");
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse(&["cmd", "--fast", "--n", "3"]);
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn list_option() {
        let a = parse(&["cmd", "--methods", "aqlm, gptq,rtn"]);
        assert_eq!(a.list_or("methods", &[]), vec!["aqlm", "gptq", "rtn"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }
}
