//! CRC-32 (IEEE 802.3 polynomial, the zlib/`crc32` variant) — dependency
//! free, table driven. The checkpoint section index stores one checksum per
//! tensor section so a lazily-opened artifact can verify exactly the bytes
//! it seek-reads without hashing the rest of the file.

/// 256-entry lookup table for the reflected polynomial `0xEDB88320`,
/// built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC-32 of `bytes` (IEEE, reflected, init/xorout `0xFFFFFFFF`) — matches
/// zlib's `crc32(0, buf, len)`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    crc ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789" under CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn detects_single_byte_flip() {
        let mut data = vec![7u8; 1024];
        let before = crc32(&data);
        data[512] ^= 0x40;
        assert_ne!(before, crc32(&data));
    }
}
