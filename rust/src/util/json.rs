//! Minimal JSON value model, recursive-descent parser, and serializer.
//!
//! `serde`/`serde_json` are unavailable offline; this module covers what the
//! system needs: artifact manifests written by `python/compile/aot.py`,
//! experiment configuration files, and machine-readable results emitted by
//! the bench harness. It parses the full JSON grammar (RFC 8259) including
//! unicode escapes, and serializes with stable key order for diffable
//! results files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap so serialization is
/// deterministic (results files diff cleanly between runs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with deterministically ordered keys.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// What the parser expected or found.
    pub msg: String,
}

impl Json {
    // ----- constructors -----
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Empty array.
    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert into an object (panics if not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Append to an array (panics if not an array).
    pub fn push(&mut self, value: Json) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(value),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    // ----- accessors -----
    /// Object field by key (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element by index (`None` for non-arrays or out of range).
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required string field, with field context in the error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    /// Required `usize` field, with field context in the error.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    /// Required numeric field, with field context in the error.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    /// Required array field, with field context in the error.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    // ----- parsing -----
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Parse a JSON file from disk.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Write pretty-printed JSON to disk.
    pub fn to_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{:#}", self))?;
        Ok(())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, lit: &str) -> Result<(), ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => {
                self.expect("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.expect("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'n') => {
                self.expect("null")?;
                Ok(Json::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.bump(); // '{'
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(":")?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.bump(); // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        if self.bump() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            self.expect("\\u")?;
                            let low = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            self.bump();
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ----- serialization -----

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        // Shortest roundtrip representation rust gives us.
        format!("{}", n)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        if f.alternate() {
            write_pretty(self, 0, &mut out);
        } else {
            write_compact(self, &mut out);
        }
        f.write_str(&out)
    }
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => out.push_str(&fmt_num(*n)),
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_compact(item, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Arr(items) if !items.is_empty() => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&pad_in);
                write_pretty(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(map) if !map.is_empty() => {
            out.push_str("{\n");
            for (i, (k, item)) in map.iter().enumerate() {
                out.push_str(&pad_in);
                escape_into(k, out);
                out.push_str(": ");
                write_pretty(item, indent + 1, out);
                if i + 1 < map.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
        other => write_compact(other, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m": {"x": [1.5, -2, true, null], "y": "s"}, "n": 7}"#;
        let v = Json::parse(src).unwrap();
        let compact = format!("{}", v);
        let pretty = format!("{:#}", v);
        assert_eq!(Json::parse(&compact).unwrap(), v);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("k", Json::from(3usize));
        let mut a = Json::arr();
        a.push(Json::from("v"));
        o.set("list", a);
        assert_eq!(o.req_usize("k").unwrap(), 3);
        assert!(o.req_str("missing").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(format!("{}", Json::Num(5.0)), "5");
        assert_eq!(format!("{}", Json::Num(5.25)), "5.25");
    }
}
