//! Self-contained utility layer.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no `rand`, `serde`, `clap`, `criterion`, `proptest`), so this module
//! provides the pieces the rest of the system needs:
//!
//! - [`rng`] — PCG64 pseudo-random generator with distribution helpers.
//! - [`json`] — minimal JSON value model, parser and serializer (used for
//!   artifact manifests, configs, and results files).
//! - [`cli`] — a small GNU-style argument parser for the `aqlm` binary.
//! - [`propcheck`] — a miniature property-based testing harness
//!   (shrinking included) standing in for `proptest`.
//! - [`timing`] — wall-clock measurement and robust summary statistics used
//!   by the custom bench harness.
//! - [`crc`] — table-driven CRC-32 used by the checkpoint section index.
//! - [`sync`] — poison-recovering lock helpers for the serving stack.

pub mod rng;
pub mod json;
pub mod cli;
pub mod propcheck;
pub mod timing;
pub mod crc;
pub mod sync;

/// Format a byte count as a human-readable string (e.g. "3.72 MiB").
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds adaptively (ns/µs/ms/s).
pub fn human_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_time_formats() {
        assert!(human_time(0.5e-9).ends_with("ns"));
        assert!(human_time(5e-6).ends_with("µs"));
        assert!(human_time(5e-3).ends_with("ms"));
        assert!(human_time(5.0).ends_with("s"));
    }
}
