//! Miniature property-based testing harness (proptest is unavailable
//! offline). Provides random input generation from a seeded [`Rng`],
//! configurable case counts, and greedy shrinking for a few common shapes
//! (integers, vectors). Used by `rust/tests/proptests.rs` to check the
//! crate's invariants: pack/unpack roundtrips, beam-search monotonicity,
//! batcher conservation, tensor algebra identities, etc.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to evaluate.
    pub cases: usize,
    /// Base seed; mixed with the property's name so each test draws an
    /// independent deterministic stream.
    pub seed: u64,
    /// Cap on shrink-candidate evaluations after a failure.
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0x9e3779b97f4a7c15, max_shrink_iters: 200 }
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cfg.cases` random inputs produced by `gen`.
/// On failure, attempts to shrink via `shrink` (which yields simpler
/// candidates) and panics with the smallest failing input's Debug repr.
pub fn check<T, G, S, P>(name: &str, cfg: &Config, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::seed_from_u64(cfg.seed ^ hash_name(name));
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink: repeatedly take the first simpler candidate that still fails.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: loop {
                if iters >= cfg.max_shrink_iters {
                    break;
                }
                for cand in shrink(&best) {
                    iters += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed at case {case}:\n  input (shrunk): {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// Convenience: property with no shrinking.
pub fn check_no_shrink<T, G, P>(name: &str, cfg: &Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check(name, cfg, gen, |_| Vec::new(), prop);
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ----- common generators -----

/// `Vec<f32>` with entries from N(0, scale), length in [1, max_len].
pub fn gen_vec_f32(max_len: usize, scale: f32) -> impl FnMut(&mut Rng) -> Vec<f32> {
    move |rng| {
        let n = 1 + rng.below(max_len);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, scale);
        v
    }
}

/// Shrinker for `Vec<T>`: halves, then removes single elements.
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        if v.len() <= 8 {
            for i in 0..v.len() {
                let mut c = v.clone();
                c.remove(i);
                if !c.is_empty() {
                    out.push(c);
                }
            }
        }
    }
    out
}

/// Shrinker for usize: towards zero.
pub fn shrink_usize(v: &usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut x = *v;
    while x > 0 {
        x /= 2;
        out.push(x);
        if out.len() > 16 {
            break;
        }
    }
    out
}

/// Assert helper producing PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let cfg = Config { cases: 32, ..Default::default() };
        check_no_shrink("sum-nonneg", &cfg, gen_vec_f32(16, 1.0), |v| {
            let s: f32 = v.iter().map(|x| x * x).sum();
            if s >= 0.0 {
                Ok(())
            } else {
                Err(format!("sum of squares negative: {s}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics() {
        let cfg = Config { cases: 4, ..Default::default() };
        check_no_shrink("always-fails", &cfg, |r: &mut Rng| r.below(10), |_| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property: all vectors have length < 4. Failing inputs shrink toward
        // minimal length-4 vectors.
        let cfg = Config { cases: 64, ..Default::default() };
        let result = std::panic::catch_unwind(|| {
            check(
                "len-lt-4",
                &cfg,
                |rng: &mut Rng| {
                    let n = 1 + rng.below(32);
                    vec![0u8; n]
                },
                shrink_vec,
                |v| if v.len() < 4 { Ok(()) } else { Err(format!("len={}", v.len())) },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Shrunk input should be close to the boundary (length 4..8).
        assert!(msg.contains("len-lt-4"));
        let shrunk_len = msg.split("len=").nth(1).unwrap().split(|c: char| !c.is_ascii_digit()).next().unwrap();
        let n: usize = shrunk_len.parse().unwrap();
        assert!(n <= 7, "shrunk to {n}");
    }

    #[test]
    fn shrink_usize_descends() {
        let c = shrink_usize(&100);
        assert!(c.windows(2).all(|w| w[1] < w[0]));
        assert_eq!(*c.last().unwrap(), 0);
    }
}
