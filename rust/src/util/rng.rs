//! PCG64 pseudo-random number generator plus sampling helpers.
//!
//! `rand` is not available offline, so this is the crate's single source of
//! randomness. PCG-XSL-RR 128/64 (O'Neill 2014): statistically strong, tiny,
//! and deterministic across platforms — important because every experiment
//! in EXPERIMENTS.md is seeded and must be reproducible bit-for-bit.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed as u128, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with explicit state/stream.
    pub fn new(init_state: u128, init_seq: u128) -> Self {
        let mut rng = Rng { state: 0, inc: (init_seq << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(init_state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-layer / per-thread use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() as u128 ^ ((tag as u128) << 64);
        let q = self.next_u64() as u128 | 1;
        Rng::new(s, q)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal sample (Box–Muller, cached spare).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method without caching to stay allocation-free.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal sample with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.f32();
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w.max(0.0) as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(9);
        let idx = r.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::seed_from_u64(13);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seed_from_u64(21);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
