//! Poison-recovering lock helpers for the serving stack.
//!
//! The server, scheduler and artifact store share mutable state behind
//! `Mutex`/`RwLock`/`Condvar`. The std primitives poison on panic: once any
//! thread panics while holding a guard, every later `lock()` returns
//! `Err(PoisonError)`. Before this module the serving stack handled that with
//! `.expect("server state poisoned")` at each site, which converts one
//! panicked request into a cascade — the panicking worker poisons the state,
//! and every other worker (and every caller of `submit`) then panics on its
//! next lock acquisition, wedging the whole process.
//!
//! Recovery is sound here because every critical section in this crate keeps
//! the shared state structurally valid at all times: queue push/pop,
//! residency-counter updates and slot installs are each completed (or not
//! started) before anything that can panic runs. A poisoned flag therefore
//! means "a thread died mid-request", not "the data is torn", and the right
//! response is to keep serving the remaining requests. The one thing that is
//! lost with the panicking thread is its in-flight request, whose channel
//! sender is dropped and surfaces as a disconnect to that caller only.
//!
//! These helpers are the designated lock shim for the crate: `aqlm-analyze`'s
//! `lock-hygiene` lint requires every `.lock()/.read()/.write()` call outside
//! this module to either go through these helpers or carry an explicit
//! `.expect("...")` message, and its `condvar-wait` rule allows
//! `Condvar::wait` only behind [`wait_recover`] at the designated server wait
//! site (see `docs/static-analysis.md`).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// See the module docs for why recovery (rather than propagating the poison)
/// is correct for this crate's critical sections.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a read guard, recovering if a previous holder panicked.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a write guard, recovering if a previous holder panicked.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Block on a condition variable, recovering the reacquired guard if the
/// state was poisoned while this thread slept.
///
/// Condvar waits can return spurious wakeups; callers must re-check their
/// predicate in a loop exactly as with `Condvar::wait`.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let res = std::thread::spawn(move || {
            let _g = m2.lock().expect("first lock cannot be poisoned");
            panic!("poison the mutex");
        })
        .join();
        assert!(res.is_err());
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rwlock_recover_survives_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let res = std::thread::spawn(move || {
            let _g = l2.write().expect("first write cannot be poisoned");
            panic!("poison the rwlock");
        })
        .join();
        assert!(res.is_err());
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }

    #[test]
    fn wait_recover_wakes_after_poisoning_notifier() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut done = lock_recover(m);
            while !*done {
                done = wait_recover(cv, done);
            }
        });
        let pair3 = Arc::clone(&pair);
        // The notifier sets the flag, notifies, then panics while still
        // holding the guard — the waiter must still observe the flag.
        let res = std::thread::spawn(move || {
            let (m, cv) = &*pair3;
            let mut done = lock_recover(m);
            *done = true;
            cv.notify_all();
            panic!("poison while notifying");
        })
        .join();
        assert!(res.is_err());
        waiter.join().expect("waiter must survive the poisoned notify");
    }
}
