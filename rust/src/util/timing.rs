//! Wall-clock measurement and robust summary statistics.
//!
//! Criterion is unavailable offline, so `rust/benches/*` (declared with
//! `harness = false`) use this module: warmup, adaptive iteration counts,
//! and median/MAD summaries that are stable on a single shared CPU core.

use std::time::Instant;

/// Summary statistics over a set of per-iteration timings (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    /// Number of samples summarized.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (midpoint average for even sample counts) — the headline
    /// number the bench tables report, robust to scheduler spikes.
    pub median: f64,
    /// Fastest sample.
    pub min: f64,
    /// Slowest sample.
    pub max: f64,
    /// Population standard deviation.
    pub stddev: f64,
}

impl Stats {
    /// Summarize a non-empty set of per-iteration timings (seconds).
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            samples[n / 2]
        } else {
            0.5 * (samples[n / 2 - 1] + samples[n / 2])
        };
        Stats { n, mean, median, min: samples[0], max: samples[n - 1], stddev: var.sqrt() }
    }
}

/// Benchmark a closure: warm up, then time `iters` batches of `batch` calls.
/// Returns per-call statistics.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, batch: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    Stats::from_samples(samples)
}

/// Adaptive variant: pick a batch size so one sample takes ≈`target_sample_s`,
/// then collect `iters` samples. Good for µs-scale kernels.
pub fn bench_adaptive<F: FnMut()>(target_sample_s: f64, iters: usize, mut f: F) -> Stats {
    // Estimate single-call cost.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let batch = ((target_sample_s / once).ceil() as usize).clamp(1, 1_000_000);
    bench(2, iters, batch, f)
}

/// Simple scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// A black box to prevent the optimizer from removing benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn stats_odd_median() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn bench_runs_and_times() {
        let mut acc = 0u64;
        let s = bench(1, 3, 10, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
    }
}
