//! Tier-1-friendly bench smoke tests: tiny-shape versions of the batch
//! sweeps in `rust/benches/`, so CI exercises the batched kernels and the
//! batched serving loop without bench-length runtimes.
//!
//! Ignored by default; run with
//!
//!     cargo test -q --release -- --ignored bench_smoke
//!
//! (or `make verify`). Each test asserts correctness (batched ==
//! sequential bit-for-bit / all requests served) and prints the measured
//! timings so the amortization is visible in CI logs.

use aqlm::bench::kernels::synthetic_weight;
use aqlm::coordinator::server::{Server, ServerConfig};
use aqlm::kernels::format::AqlmShape;
use aqlm::kernels::matvec::PackedAqlm;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::linear::Linear;
use aqlm::nn::model::Model;
use aqlm::util::rng::Rng;
use aqlm::util::timing::{bench_adaptive, black_box};

#[test]
#[ignore = "bench smoke — run explicitly (see module docs)"]
fn bench_smoke_batch_kernels() {
    let (d_out, d_in) = (256, 128);
    let mut rng = Rng::seed_from_u64(1);
    println!("| config | n | n x matvec | matmat | speedup |");
    println!("| ------ | - | ---------- | ------ | ------- |");
    for shape in [AqlmShape::new(2, 8, 8), AqlmShape::new(3, 5, 4)] {
        let w = synthetic_weight(d_out, d_in, shape, &mut rng);
        let packed = PackedAqlm::from_weight(&w);
        for n in [1usize, 4, 8, 16] {
            let xs: Vec<f32> = (0..n * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y_seq = vec![0.0f32; n * d_out];
            let mut lut = Vec::new();
            let seq = bench_adaptive(0.01, 5, || {
                for b in 0..n {
                    packed.matvec_auto(
                        black_box(&xs[b * d_in..(b + 1) * d_in]),
                        &mut lut,
                        &mut y_seq[b * d_out..(b + 1) * d_out],
                    );
                }
            });
            let mut y_bat = vec![0.0f32; n * d_out];
            let mut blut = Vec::new();
            let bat = bench_adaptive(0.01, 5, || {
                packed.matmat_auto(black_box(&xs), n, &mut blut, &mut y_bat);
            });
            // Correctness: one batched call == n sequential calls, bitwise.
            for i in 0..n * d_out {
                assert_eq!(y_bat[i].to_bits(), y_seq[i].to_bits(), "index {i} diverged");
            }
            println!(
                "| {} | {} | {} | {} | x{:.2} |",
                shape.name(),
                n,
                aqlm::util::human_time(seq.median),
                aqlm::util::human_time(bat.median),
                seq.median / bat.median
            );
        }
    }
}

#[test]
#[ignore = "bench smoke — run explicitly (see module docs)"]
fn bench_smoke_server_batch_sweep() {
    // Tiny AQLM-weighted model through the batched serving loop at
    // max_batch ∈ {1, 8}: all requests must be served and greedy output
    // must be identical across batch sizes (scheduling-independence).
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab_size = 64;
    cfg.max_seq = 64;
    cfg.n_layers = 2;
    let mut rng = Rng::seed_from_u64(2);
    let mut model = Model::init(&cfg, &mut rng);
    for block in &mut model.blocks {
        for (_, lin) in block.linears_mut() {
            let (d_out, d_in) = (lin.d_out(), lin.d_in());
            *lin = Linear::aqlm(synthetic_weight(d_out, d_in, AqlmShape::new(2, 6, 4), &mut rng));
        }
    }
    let n_req = 8;
    let max_new = 16;
    let mut outputs: Vec<Vec<Vec<u32>>> = Vec::new();
    for max_batch in [1usize, 8] {
        let server = Server::start(model.clone(), ServerConfig { max_batch, seed: 0, ..Default::default() });
        let rxs: Vec<_> = (0..n_req)
            .map(|i| server.submit(vec![1, 2 + i as u32], max_new, 0.0))
            .collect();
        let toks: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap().tokens)
            .collect();
        let stats = server.shutdown();
        assert_eq!(stats.requests, n_req);
        println!(
            "max_batch {max_batch}: {:.1} tok/s ({} tokens in {:.3}s)",
            stats.tokens_per_second(),
            stats.tokens_generated,
            stats.wall_s
        );
        outputs.push(toks);
    }
    assert_eq!(outputs[0], outputs[1], "greedy output depends on max_batch");
}
