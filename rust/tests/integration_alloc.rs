//! Integration: automatic rate-distortion bit allocation (the
//! `--auto-bits` engine, `quant::alloc`) on a *trained* model — the probe
//! leaves the model untouched, the emitted (coalesced) policy hits the
//! requested budget from below, round-trips through the policy grammar,
//! reproduces its predicted budget through the real pipeline, allocates
//! monotonically in the budget, and does not lose to the uniform AQLM
//! point at the same budget. The per-block test covers `--granularity
//! block`: glob (`b<k>.*`) rules, O(blocks) rule count, and exact budget
//! reproduction.

use aqlm::coordinator::pipeline::quantize_model;
use aqlm::coordinator::train::{train_native, TrainConfig};
use aqlm::data::dataset::{DataBundle, DataSizes, TokenDataset};
use aqlm::eval::ppl::perplexity;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::model::Model;
use aqlm::quant::alloc::{allocate, auto_allocate, default_candidates, Granularity};
use aqlm::quant::spec::LayerPolicy;
use aqlm::util::rng::Rng;

struct Setup {
    bundle: DataBundle,
    model: Model,
    calib: Vec<u32>,
    n_seqs: usize,
    seq: usize,
}

fn trained_setup(seed: u64) -> Setup {
    let bundle = DataBundle::generate(
        seed,
        DataSizes { train_tokens: 60_000, eval_tokens: 2_048, calib_tokens: 8_192, seq_len: 48 },
    );
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(seed);
    let mut model = Model::init(&cfg, &mut rng);
    let tcfg = TrainConfig { steps: 200, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let (n_seqs, seq) = (6usize, 48usize);
    let calib = {
        let data = TokenDataset { tokens: bundle.calib.tokens.clone(), seq_len: seq };
        let (c, _) = data.sample_batch(n_seqs, &mut rng);
        c
    };
    Setup { bundle, model, calib, n_seqs, seq }
}

#[test]
fn auto_allocation_end_to_end_on_trained_model() {
    let s = trained_setup(31);
    let target = 2.5;
    // Modest FT keeps the three pipeline runs below test-sized.
    let candidates = default_candidates(&s.model.cfg, target, 8, true);
    assert!(candidates.len() >= 2, "degenerate candidate grid");

    let mut probe_model = s.model.clone();
    let mut prng = Rng::seed_from_u64(7);
    let auto = auto_allocate(
        &mut probe_model,
        &s.calib,
        s.n_seqs,
        s.seq,
        target,
        &candidates,
        Granularity::PerLayer,
        &mut prng,
    )
    .unwrap();

    // The probe is a dry run: the probed model's weights are untouched.
    for (b_probe, b_orig) in probe_model.blocks.iter_mut().zip(&s.model.blocks) {
        for ((name, lin), (_, lin0)) in b_probe.linears_mut().into_iter().zip(b_orig.linears()) {
            assert!(!lin.is_quantized(), "{name}");
            assert!(lin.weight_owned().allclose(&lin0.weight_owned(), 0.0), "{name}");
        }
    }
    assert_eq!(auto.table[0].layer, "b0.wq", "probe rows follow model order");

    // (1) Budget: never above the request, within grid granularity below.
    assert!(auto.avg_bits() <= target + 1e-9, "overshot: {}", auto.avg_bits());
    assert!(auto.avg_bits() > target - 0.45, "undershot: {}", auto.avg_bits());

    // (2) The emitted policy is an ordinary policy string: Display ↔ parse
    // closed under allocator output, coalesced to at most one rule per
    // layer (glob rules wherever layers agree), and it still routes every
    // probed layer to exactly its chosen candidate.
    let printed = auto.policy.to_string();
    let reparsed = LayerPolicy::parse(&printed).unwrap();
    assert_eq!(reparsed, auto.policy, "policy did not round-trip: {printed}");
    assert!(auto.policy.rules.len() <= auto.table.len());
    for (row, &c) in auto.table.iter().zip(&auto.allocation.choice) {
        assert_eq!(
            reparsed.spec_for(&row.layer),
            Some(&auto.candidates[c].emit),
            "{} misrouted by the coalesced policy {printed}",
            row.layer
        );
    }

    // (3) The *reparsed* policy runs through the pipeline and lands exactly
    // the predicted budget (storage depends only on the candidate shapes).
    let mut m_auto = s.model.clone();
    let mut rng = Rng::seed_from_u64(3);
    let rep_auto =
        quantize_model(&mut m_auto, &s.calib, s.n_seqs, s.seq, &reparsed, &mut rng).unwrap();
    assert!(
        (rep_auto.avg_bits - auto.avg_bits()).abs() < 1e-6,
        "predicted {} bits, pipeline measured {}",
        auto.avg_bits(),
        rep_auto.avg_bits
    );
    let ppl_auto = perplexity(&mut m_auto, &s.bundle.eval_wiki, 8);
    let ppl_base = perplexity(&mut s.model.clone(), &s.bundle.eval_wiki, 8);
    assert!(ppl_auto.is_finite() && ppl_auto < ppl_base * 6.0, "auto model unusable: {ppl_auto}");

    // (4) Against uniform at the same budget: the widest single candidate
    // that fits the target (what `--method aqlm:bits=2.5` effectively
    // picks) must not beat the solved allocation.
    let uniform_avg = |c: usize| {
        let (mut bits, mut params) = (0.0f64, 0usize);
        for row in &auto.table {
            bits += row.bits(c) * row.params as f64;
            params += row.params;
        }
        bits / params as f64
    };
    let comparer = (0..candidates.len())
        .filter(|&c| uniform_avg(c) <= target + 1e-9)
        .max_by(|&a, &b| uniform_avg(a).total_cmp(&uniform_avg(b)));
    if let Some(c) = comparer {
        let mut m_uni = s.model.clone();
        let mut rng_u = Rng::seed_from_u64(3);
        let uniform = LayerPolicy::uniform(candidates[c].emit);
        let rep_uni =
            quantize_model(&mut m_uni, &s.calib, s.n_seqs, s.seq, &uniform, &mut rng_u).unwrap();
        assert!(rep_uni.avg_bits <= target + 1e-6, "comparer over budget");
        let ppl_uni = perplexity(&mut m_uni, &s.bundle.eval_wiki, 8);
        // The allocator spends the same budget where the probe measured it
        // to matter, so it must not lose to uniform; the tolerance absorbs
        // eval noise at this model scale (figure f9 shows the actual wins).
        assert!(
            ppl_auto < ppl_uni * 1.05,
            "auto ({:.3} bits, ppl {ppl_auto:.3}) lost to uniform {} ({:.3} bits, ppl {ppl_uni:.3})",
            rep_auto.avg_bits,
            candidates[c].emit,
            rep_uni.avg_bits
        );
    }

    // (5) Monotonicity on the real probe table: raising the budget never
    // narrows a layer.
    let a_lo = allocate(&auto.table, 2.2).unwrap();
    let a_hi = allocate(&auto.table, 3.2).unwrap();
    for (j, row) in auto.table.iter().enumerate() {
        assert!(
            row.bits(a_hi.choice[j]) >= row.bits(a_lo.choice[j]) - 1e-12,
            "{} narrowed when the budget rose: {} -> {}",
            row.layer,
            row.bits(a_lo.choice[j]),
            row.bits(a_hi.choice[j])
        );
    }
}

/// `--auto-bits 2.5 --granularity block` end to end on a trained nano:
/// the emitted policy is made of glob (`b<k>.*`) rules — O(blocks) of
/// them, not O(layers) — hits the budget from below, round-trips through
/// `LayerPolicy::parse`, and reproduces the predicted avg_bits exactly
/// through the real pipeline.
#[test]
fn per_block_auto_allocation_emits_glob_policy_and_reproduces_bits() {
    let s = trained_setup(47);
    let target = 2.5;
    let candidates = default_candidates(&s.model.cfg, target, 8, true);

    let mut probe_model = s.model.clone();
    let mut prng = Rng::seed_from_u64(13);
    let auto = auto_allocate(
        &mut probe_model,
        &s.calib,
        s.n_seqs,
        s.seq,
        target,
        &candidates,
        Granularity::PerBlock,
        &mut prng,
    )
    .unwrap();
    let printed = auto.policy.to_string();

    // Budget: never above the request.
    assert!(auto.avg_bits() <= target + 1e-9, "overshot: {}", auto.avg_bits());

    // The policy is glob rules at block granularity: every pattern is
    // `b<k>.*` (or the single catch-all `*` if all blocks agreed), and
    // there are at most as many rules as blocks — the O(blocks) regression
    // guard on a real model.
    let n_blocks = s.model.blocks.len();
    assert!(
        auto.policy.rules.len() <= n_blocks,
        "{} rules for {n_blocks} blocks: {printed}",
        auto.policy.rules.len()
    );
    assert!(
        auto.policy.rules.iter().all(|(pat, _)| {
            pat == "*"
                || (pat.starts_with('b')
                    && pat.ends_with(".*")
                    && pat[1..pat.len() - 2].bytes().all(|b| b.is_ascii_digit()))
        }),
        "non-block-glob rule in {printed}"
    );
    // Every layer of one block routes to one spec.
    for (bi, block) in s.model.blocks.iter().enumerate() {
        let specs: Vec<_> = block
            .linears()
            .into_iter()
            .map(|(name, _)| *auto.policy.spec_for(&format!("b{bi}.{name}")).unwrap())
            .collect();
        assert!(specs.windows(2).all(|w| w[0] == w[1]), "block {bi} not uniform");
    }

    // Round-trip, then reproduce the predicted budget through the real
    // pipeline (storage depends only on the candidate shapes, which probe
    // and emit specs share).
    let reparsed = LayerPolicy::parse(&printed).unwrap();
    assert_eq!(reparsed, auto.policy, "policy did not round-trip: {printed}");
    let mut m_auto = s.model.clone();
    let mut rng = Rng::seed_from_u64(5);
    let rep =
        quantize_model(&mut m_auto, &s.calib, s.n_seqs, s.seq, &reparsed, &mut rng).unwrap();
    assert!(
        (rep.avg_bits - auto.avg_bits()).abs() < 1e-6,
        "predicted {} bits, pipeline measured {}",
        auto.avg_bits(),
        rep.avg_bits
    );
    let ppl = perplexity(&mut m_auto, &s.bundle.eval_wiki, 8);
    assert!(ppl.is_finite(), "per-block auto model unusable");
}
