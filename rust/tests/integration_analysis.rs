//! The analyzer run against this repository itself.
//!
//! This is the same check `make analyze` performs in CI, executed as a test
//! so `cargo test` alone also catches invariant regressions: the checked-in
//! allowlist must make the real crate pass, every allowlist entry must
//! still be earning its keep, and removing the allowlist must surface the
//! known contract-defining reduction sites (i.e. the lints are not
//! vacuously green).

use std::path::Path;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn repo_passes_with_checked_in_allowlist() {
    let report = aqlm::analysis::analyze_repo(repo_root()).expect("analysis must run");
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.is_clean(),
        "rust/src must be lint-clean under analyze.allow:\n{}",
        rendered.join("\n")
    );
    assert!(
        report.files_scanned > 60,
        "walker saw only {} files — the rust/src sweep is broken",
        report.files_scanned
    );
    assert!(report.allow_entries > 0, "the checked-in allowlist must parse");
    assert!(
        report.suppressed >= report.allow_entries,
        "{} entries suppressed only {} findings — stale entries should have failed above",
        report.allow_entries,
        report.suppressed
    );
}

#[test]
fn lints_are_not_vacuous_without_the_allowlist() {
    // The bit-exactness contract sites in kernels/simd.rs and the router
    // backward in nn/moe.rs must be *visible* to the float-reassoc lint;
    // only the justified allowlist keeps the build green.
    for rel in ["rust/src/kernels/simd.rs", "rust/src/nn/moe.rs"] {
        let text = std::fs::read_to_string(repo_root().join(rel)).expect("source readable");
        let report = aqlm::analysis::analyze_sources(&[(rel.to_string(), text)], "")
            .expect("analysis must run");
        assert!(
            report.findings.iter().any(|f| f.lint == "float-reassoc"),
            "{rel}: expected a float-reassoc finding with an empty allowlist"
        );
    }
}

#[test]
fn unused_allowlist_entry_fails_as_stale() {
    let sources = vec![("rust/src/nn/clean.rs".to_string(), "fn f() {}\n".to_string())];
    let allow = "float-reassoc | nn/gone.rs | .sum() | the site this covered was removed\n";
    let report = aqlm::analysis::analyze_sources(&sources, allow).expect("analysis must run");
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].lint, "stale-allowlist");
    assert_eq!(report.findings[0].file, "analyze.allow");
}
