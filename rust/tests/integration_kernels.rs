//! Integration over the kernel execution knobs (`KernelConfig`): every
//! parallel/SIMD kernel variant must be **bit-for-bit identical** (0 ulp)
//! to its scalar-serial oracle, the quantizer's parallel inner loops must
//! be byte-deterministic, and a server running with a non-serial config
//! must emit exactly the tokens of an offline serial decode.
//!
//! See `docs/kernels.md` for the contract these tests enforce.

use aqlm::bench::kernels::synthetic_weight;
use aqlm::coordinator::server::{Server, ServerConfig};
use aqlm::kernels::config::KernelConfig;
use aqlm::kernels::format::{AqlmShape, PackedSpqr};
use aqlm::kernels::matvec::PackedAqlm;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::linear::Linear;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::beam::beam_search_sweep_threads;
use aqlm::quant::aqlm::kmeans::kmeans_threads;
use aqlm::quant::aqlm::layer::{AqlmLayerConfig, LayerQuantizer};
use aqlm::quant::CalibData;
use aqlm::tensor::ops::matmul_bt;
use aqlm::tensor::Tensor;
use aqlm::util::propcheck::{check_no_shrink, Config};
use aqlm::util::rng::Rng;

/// Explicit thread counts exercised everywhere (1 = serial baseline; 3 is
/// deliberately not a divisor of most row counts; 8 usually exceeds the
/// row count of the small shapes, exercising the clamp).
const THREADS: [usize; 4] = [1, 2, 3, 8];
/// Batch widths for the matmat / batched kernels.
const BATCHES: [usize; 4] = [1, 4, 8, 16];

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: bit mismatch at [{i}]: {g} vs {w}"
        );
    }
}

/// Short display tag for a config, e.g. `t4+simd` (mirrors the bench's
/// method-string suffix).
fn cfg_tag(kc: KernelConfig) -> String {
    format!("t{}{}", kc.threads, if kc.simd { "+simd" } else { "" })
}

/// The full threads × simd grid, serial-scalar first.
fn all_cfgs() -> Vec<KernelConfig> {
    let mut out = Vec::new();
    for &threads in &THREADS {
        for &simd in &[false, true] {
            out.push(KernelConfig { threads, simd });
        }
    }
    out
}

fn randn(n: usize, rng: &mut Rng) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

// ------------------------------------------------------- AQLM kernel parity

/// Every AQLM kernel variant at every (threads, simd) setting vs the plain
/// scalar-serial oracle, at 0 ulp, over a spread of shapes: byte-aligned
/// codes, the 3×5-bit multi-codebook format from the paper, rows below the
/// thread count, and a >8-bit code width (the scalar-only LUT path).
#[test]
fn aqlm_kernels_bitexact_across_threads_and_simd() {
    let shapes = [
        (37, 48, AqlmShape::new(2, 8, 8)),  // byte codes, ragged vs 8-chunking
        (64, 32, AqlmShape::new(3, 5, 16)), // 3 codebooks × 5-bit, g=16
        (5, 24, AqlmShape::new(2, 4, 8)),   // d_out < max thread count
        (33, 32, AqlmShape::new(1, 9, 8)),  // code_bits > 8: scalar LUT path
    ];
    let mut rng = Rng::seed_from_u64(0xA11CE);
    for &(d_out, d_in, shape) in &shapes {
        let mut w = synthetic_weight(d_out, d_in, shape, &mut rng);
        // Non-unit per-row scales so the final multiply is load-bearing.
        w.scales = (0..d_out).map(|_| 0.5 + rng.f32()).collect();
        let p = PackedAqlm::from_weight(&w);
        let tag = format!("{d_out}x{d_in} {shape:?}");

        let x = randn(d_in, &mut rng);
        let mut want_dec = vec![0.0f32; d_out];
        p.matvec_decode(&x, &mut want_dec);
        let mut lut = vec![0.0f32; p.lut_len()];
        let mut want_lut = vec![0.0f32; d_out];
        p.matvec_lut(&x, &mut lut, &mut want_lut);
        let mut auto_scratch = Vec::new();
        let mut want_auto = vec![0.0f32; d_out];
        p.matvec_auto(&x, &mut auto_scratch, &mut want_auto);

        for cfg in all_cfgs() {
            let ctag = format!("{tag} {}", cfg_tag(cfg));
            let mut y = vec![0.0f32; d_out];
            p.matvec_decode_with(&x, &mut y, cfg);
            assert_bits_eq(&y, &want_dec, &format!("matvec_decode {ctag}"));
            y.fill(f32::NAN);
            p.matvec_lut_with(&x, &mut lut, &mut y, cfg);
            assert_bits_eq(&y, &want_lut, &format!("matvec_lut {ctag}"));
            y.fill(f32::NAN);
            p.matvec_auto_with(&x, &mut auto_scratch, &mut y, cfg);
            assert_bits_eq(&y, &want_auto, &format!("matvec_auto {ctag}"));
        }

        for &n in &BATCHES {
            let xs = randn(n * d_in, &mut rng);
            let mut want_mm_dec = vec![0.0f32; n * d_out];
            p.matmat_decode(&xs, n, &mut want_mm_dec);
            let mut blut = vec![0.0f32; n * p.lut_len()];
            let mut want_mm_lut = vec![0.0f32; n * d_out];
            p.matmat_lut(&xs, n, &mut blut, &mut want_mm_lut);
            let mut want_mm_auto = vec![0.0f32; n * d_out];
            p.matmat_auto(&xs, n, &mut auto_scratch, &mut want_mm_auto);
            for cfg in all_cfgs() {
                let ctag = format!("{tag} n={n} {}", cfg_tag(cfg));
                let mut ys = vec![0.0f32; n * d_out];
                p.matmat_decode_with(&xs, n, &mut ys, cfg);
                assert_bits_eq(&ys, &want_mm_dec, &format!("matmat_decode {ctag}"));
                ys.fill(f32::NAN);
                p.matmat_lut_with(&xs, n, &mut blut, &mut ys, cfg);
                assert_bits_eq(&ys, &want_mm_lut, &format!("matmat_lut {ctag}"));
                ys.fill(f32::NAN);
                p.matmat_auto_with(&xs, n, &mut auto_scratch, &mut ys, cfg);
                assert_bits_eq(&ys, &want_mm_auto, &format!("matmat_auto {ctag}"));
            }
        }
    }
}

// ------------------------------------------------------- SpQR kernel parity

/// Random packed-SpQR layer; `d_in` is deliberately allowed to be ragged
/// (`d_in % group != 0`) so the tail-group path is exercised.
fn random_spqr(d_out: usize, d_in: usize, bits: usize, rng: &mut Rng) -> PackedSpqr {
    let group = 16;
    let n_groups = d_in.div_ceil(group);
    let codes: Vec<u16> = (0..d_out * d_in).map(|_| rng.below(1 << bits) as u16).collect();
    let scales: Vec<f32> = (0..d_out * n_groups).map(|_| 0.01 + rng.f32() * 0.1).collect();
    let zeros: Vec<f32> =
        (0..d_out * n_groups).map(|_| rng.f32() * ((1 << bits) - 1) as f32).collect();
    // ~8% outliers at strictly ascending flat positions.
    let outliers: Vec<(usize, f32)> =
        (0..d_out * d_in).step_by(13).map(|flat| (flat, rng.f32() * 2.0 - 1.0)).collect();
    PackedSpqr::from_parts(d_out, d_in, group, bits, &codes, scales, zeros, &outliers)
        .expect("valid synthetic SpQR layer")
}

/// SpQR fused matvec + batched matvec at every (threads, simd) setting vs
/// the scalar-serial oracle, at 0 ulp, including ragged `d_in % 16 != 0`.
#[test]
fn spqr_kernels_bitexact_across_threads_and_simd() {
    let shapes = [
        (40, 50, 3), // ragged tail group (50 % 16 == 2)
        (7, 33, 4),  // d_out < max thread count, ragged
        (48, 64, 8), // aligned, widest code
    ];
    let mut rng = Rng::seed_from_u64(0x5B9);
    for &(d_out, d_in, bits) in &shapes {
        let q = random_spqr(d_out, d_in, bits, &mut rng);
        let tag = format!("spqr {d_out}x{d_in} b{bits}");

        let x = randn(d_in, &mut rng);
        let mut scratch = Vec::new();
        let mut want = vec![0.0f32; d_out];
        q.matvec(&x, &mut scratch, &mut want);
        for cfg in all_cfgs() {
            let ctag = format!("{tag} {}", cfg_tag(cfg));
            let mut y = vec![f32::NAN; d_out];
            q.matvec_with(&x, &mut scratch, &mut y, cfg);
            assert_bits_eq(&y, &want, &format!("matvec {ctag}"));
        }

        for &n in &BATCHES {
            let xs = randn(n * d_in, &mut rng);
            let mut want_b = vec![0.0f32; n * d_out];
            q.matvec_batch(&xs, n, &mut scratch, &mut want_b);
            for cfg in all_cfgs() {
                let ctag = format!("{tag} n={n} {}", cfg_tag(cfg));
                let mut ys = vec![f32::NAN; n * d_out];
                q.matvec_batch_with(&xs, n, &mut scratch, &mut ys, cfg);
                assert_bits_eq(&ys, &want_b, &format!("matvec_batch {ctag}"));
            }
        }
    }
}

// --------------------------------------------------------------- properties

/// Property: for random AQLM shapes, inputs, thread counts and SIMD flags,
/// the configured LUT and decode matvecs equal the serial-scalar oracle
/// bit-for-bit. Randomizes what the fixed-shape test above pins.
#[test]
fn prop_aqlm_matvec_thread_and_simd_invariant() {
    check_no_shrink(
        "aqlm-matvec-knob-invariance",
        &Config { cases: 48, ..Default::default() },
        |rng: &mut Rng| {
            let groups = 1 + rng.below(5);
            let g = [4, 8, 16][rng.below(3)];
            (
                rng.below(1 << 30) as u64,        // weight/input seed
                1 + rng.below(48),                // d_out
                groups * g,                       // d_in
                g,                                // group
                1 + rng.below(3),                 // n_codebooks
                3 + rng.below(6),                 // code_bits (byte range)
                THREADS[rng.below(THREADS.len())],
                rng.below(2) == 1,                // simd
            )
        },
        |&(seed, d_out, d_in, g, m, bits, threads, simd)| {
            let mut rng = Rng::seed_from_u64(seed);
            let w = synthetic_weight(d_out, d_in, AqlmShape::new(m, bits, g), &mut rng);
            let p = PackedAqlm::from_weight(&w);
            let x = randn(d_in, &mut rng);
            let cfg = KernelConfig { threads, simd };
            let mut lut = vec![0.0f32; p.lut_len()];
            let (mut want, mut got) = (vec![0.0f32; d_out], vec![0.0f32; d_out]);
            p.matvec_lut(&x, &mut lut, &mut want);
            p.matvec_lut_with(&x, &mut lut, &mut got, cfg);
            if got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("matvec_lut diverged at t{threads} simd={simd}"));
            }
            p.matvec_decode(&x, &mut want);
            p.matvec_decode_with(&x, &mut got, cfg);
            if got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("matvec_decode diverged at t{threads} simd={simd}"));
            }
            Ok(())
        },
    );
}

/// Property: the fused SpQR matvec is knob-invariant over random (often
/// ragged) shapes, bit widths, outlier patterns, threads, and SIMD.
#[test]
fn prop_spqr_matvec_thread_and_simd_invariant() {
    check_no_shrink(
        "spqr-matvec-knob-invariance",
        &Config { cases: 48, ..Default::default() },
        |rng: &mut Rng| {
            (
                rng.below(1 << 30) as u64,        // layer/input seed
                1 + rng.below(40),                // d_out
                1 + rng.below(70),                // d_in (ragged vs g=16 often)
                2 + rng.below(7),                 // bits
                THREADS[rng.below(THREADS.len())],
                rng.below(2) == 1,                // simd
            )
        },
        |&(seed, d_out, d_in, bits, threads, simd)| {
            let mut rng = Rng::seed_from_u64(seed);
            let q = random_spqr(d_out, d_in, bits, &mut rng);
            let x = randn(d_in, &mut rng);
            let mut scratch = Vec::new();
            let (mut want, mut got) = (vec![0.0f32; d_out], vec![0.0f32; d_out]);
            q.matvec(&x, &mut scratch, &mut want);
            q.matvec_with(&x, &mut scratch, &mut got, KernelConfig { threads, simd });
            if got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("spqr matvec diverged at t{threads} simd={simd}"));
            }
            Ok(())
        },
    );
}

// -------------------------------------------------- quantizer determinism

/// Parallel beam search commits byte-identical codes and bit-identical
/// loss at any thread count, on a realistic (random-calibration) XXᵀ.
#[test]
fn beam_search_threads_byte_identical() {
    let mut rng = Rng::seed_from_u64(11);
    let (d_out, d_in) = (24, 32);
    let base = synthetic_weight(d_out, d_in, AqlmShape::new(2, 4, 8), &mut rng);
    let w = Tensor::randn(&[d_out, d_in], 1.0, &mut rng);
    let x = Tensor::randn(&[d_in, 40], 1.0, &mut rng);
    let xxt = matmul_bt(&x, &x);

    let mut q1 = base.clone();
    let loss1 = beam_search_sweep_threads(&mut q1, &w, &xxt, 3, 1);
    for threads in [2, 4, 8] {
        let mut qt = base.clone();
        let losst = beam_search_sweep_threads(&mut qt, &w, &xxt, 3, threads);
        assert_eq!(qt.codes, q1.codes, "beam codes diverged at threads={threads}");
        assert_eq!(
            losst.to_bits(),
            loss1.to_bits(),
            "beam loss diverged at threads={threads}"
        );
    }
}

/// Parallel k-means assignment leaves centroids, assignments, and rng
/// consumption byte-identical to serial at any thread count.
#[test]
fn kmeans_threads_byte_identical() {
    let points = Tensor::randn(&[75, 6], 1.0, &mut Rng::seed_from_u64(21));
    let (c1, a1) = kmeans_threads(&points, 9, 12, &mut Rng::seed_from_u64(22), 1);
    for threads in [2, 4, 8] {
        let (ct, at) = kmeans_threads(&points, 9, 12, &mut Rng::seed_from_u64(22), threads);
        assert_eq!(at, a1, "kmeans assignments diverged at threads={threads}");
        let bits1: Vec<u32> = c1.data().iter().map(|v| v.to_bits()).collect();
        let bitst: Vec<u32> = ct.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(bitst, bits1, "kmeans centroids diverged at threads={threads}");
    }
}

// ------------------------------------------------- end-to-end token parity

fn nano_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab_size = 64;
    cfg.max_seq = 48;
    Model::init(&cfg, &mut Rng::seed_from_u64(seed))
}

/// Quantize a nano model, decode it offline with the serial-scalar config,
/// then serve it with threads=4 + SIMD: the greedy token streams must be
/// identical — the whole-stack consequence of the per-kernel 0-ulp parity.
#[test]
fn parallel_simd_server_emits_identical_greedy_tokens() {
    let mut m = nano_model(7);
    let mut rng = Rng::seed_from_u64(8);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(AqlmShape::new(2, 5, 4)));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let mut offline = m.clone();
    offline.kernel = KernelConfig::serial();
    let expected = offline.generate(&[5, 9, 2], 8, 0.0, &mut Rng::seed_from_u64(0));

    let server = Server::start(
        m,
        ServerConfig { kernel: KernelConfig { threads: 4, simd: true }, ..Default::default() },
    );
    let resp = server.submit(vec![5, 9, 2], 8, 0.0).recv().unwrap();
    assert_eq!(resp.tokens, expected, "threads=4+simd server diverged from serial offline");
    server.shutdown();
}
