//! Bounded-divergence contract for the quantized KV cache
//! (`docs/kvcache.md`), checked on a *trained* nano model so logit margins
//! are realistic rather than the near-uniform noise of a random init:
//!
//!   * kv8 greedy decoding is token-identical to the f32 cache for ≥ 64
//!     steps;
//!   * kv4 may diverge, but not before generated-token index 8 (the
//!     documented budget).
//!
//! The codec round-trip error bound itself is property-tested in
//! `proptests.rs`; this file pins the end-to-end decode consequence.

use aqlm::coordinator::train::{train_native, TrainConfig};
use aqlm::data::dataset::{DataBundle, DataSizes};
use aqlm::nn::config::ModelConfig;
use aqlm::nn::kvcache::KvBits;
use aqlm::nn::model::Model;
use aqlm::util::rng::Rng;

/// First index at which `a` and `b` disagree (a length mismatch counts as
/// divergence at the shorter length), or `None` when identical.
fn first_divergence(a: &[u32], b: &[u32]) -> Option<usize> {
    let n = a.len().min(b.len());
    for i in 0..n {
        if a[i] != b[i] {
            return Some(i);
        }
    }
    if a.len() != b.len() {
        Some(n)
    } else {
        None
    }
}

#[test]
#[cfg_attr(miri, ignore)] // trains a model — far too slow under miri
fn trained_nano_kv_divergence_contract() {
    let bundle = DataBundle::generate(
        41,
        DataSizes { train_tokens: 60_000, eval_tokens: 2_048, calib_tokens: 8_192, seq_len: 48 },
    );
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(41);
    let mut model = Model::init(&cfg, &mut rng);
    let tcfg = TrainConfig { steps: 200, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);

    // Realistic prompt: the first 8 calibration tokens (same distribution
    // the model was trained on, so greedy margins are sharp).
    let prompt: Vec<u32> = bundle.calib.tokens[..8].to_vec();
    let steps = 64;
    assert!(prompt.len() + steps <= model.cfg.max_seq, "contract run must fit the context");

    let f32_out = model.generate(&prompt, steps, 0.0, &mut Rng::seed_from_u64(0));

    // kv8: token-identical to the f32 cache for the full 64-step run.
    let kv8_out =
        model.generate_with_kv_bits(&prompt, steps, 0.0, &mut Rng::seed_from_u64(0), KvBits::B8);
    assert_eq!(
        kv8_out, f32_out,
        "kv8 greedy decode must be token-identical to f32 for {steps} steps"
    );

    // F32 through the _with path is the same code path as generate().
    let f32_again =
        model.generate_with_kv_bits(&prompt, steps, 0.0, &mut Rng::seed_from_u64(0), KvBits::F32);
    assert_eq!(f32_again, f32_out, "KvBits::F32 must be exactly generate()");

    // kv4: bounded divergence. The outputs may differ, but the first
    // divergent *generated* token must come at index >= 8 — early drift
    // would mean the codec error is corrupting attention immediately
    // rather than accumulating slowly.
    let kv4_out =
        model.generate_with_kv_bits(&prompt, steps, 0.0, &mut Rng::seed_from_u64(0), KvBits::B4);
    assert_eq!(&kv4_out[..prompt.len()], &prompt[..], "kv4 output must start with the prompt");
    match first_divergence(&kv4_out, &f32_out) {
        None => {} // bit-identical run — comfortably within budget
        Some(i) => {
            let gen_idx = i.saturating_sub(prompt.len());
            assert!(
                i >= prompt.len() && gen_idx >= 8,
                "kv4 diverged at generated index {gen_idx} (< 8-token budget)"
            );
        }
    }

    // kv3 has no token-level budget (3-bit KV is a capacity experiment,
    // not a fidelity contract) but must still decode the full run without
    // panicking and stay inside the vocabulary.
    let kv3_out =
        model.generate_with_kv_bits(&prompt, steps, 0.0, &mut Rng::seed_from_u64(0), KvBits::B3);
    assert!(kv3_out.len() > prompt.len(), "kv3 run must generate tokens");
    assert!(
        kv3_out.iter().all(|&t| (t as usize) < model.cfg.vocab_size),
        "kv3 produced out-of-vocab tokens"
    );
}
