//! Integration: training on TinyLang produces a model whose capabilities
//! are real (above-chance zero-shot accuracy, low PPL) and that survives a
//! checkpoint roundtrip — the substrate every paper table relies on.

use aqlm::coordinator::train::{train_native, TrainConfig};
use aqlm::data::dataset::{DataBundle, DataSizes};
use aqlm::data::tasks::Task;
use aqlm::eval::ppl::perplexity;
use aqlm::eval::zeroshot::eval_suite;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::model::Model;
use aqlm::util::rng::Rng;

fn quick_bundle() -> DataBundle {
    DataBundle::generate(
        5,
        DataSizes { train_tokens: 60_000, eval_tokens: 2_048, calib_tokens: 4_096, seq_len: 48 },
    )
}

#[test]
fn trained_nano_learns_language_structure() {
    let bundle = quick_bundle();
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(6);
    let mut model = Model::init(&cfg, &mut rng);
    let ppl_before = perplexity(&mut model, &bundle.eval_wiki, 8);
    let tcfg = TrainConfig { steps: 120, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let ppl_after = perplexity(&mut model, &bundle.eval_wiki, 8);
    assert!(
        ppl_after < ppl_before * 0.25,
        "training barely helped: {ppl_before:.1} -> {ppl_after:.1}"
    );
    // Zero-shot: agreement (2-way) should be clearly above chance after
    // this much training; hard tasks may still be near chance.
    let suite = eval_suite(
        &mut model,
        &bundle.tokenizer,
        &bundle.world,
        &[Task::Agreement, Task::Order],
        60,
        9,
    );
    for (task, acc) in &suite.per_task {
        assert!(*acc > 55.0, "{}: accuracy {acc} not above chance", task.name());
    }
    // Checkpoint roundtrip preserves behaviour.
    let path = std::env::temp_dir().join("aqlm_integration_nano.ckpt");
    model.save(&path).unwrap();
    let mut loaded = Model::load(&path).unwrap();
    let ppl_loaded = perplexity(&mut loaded, &bundle.eval_wiki, 8);
    assert!((ppl_loaded - ppl_after).abs() < 1e-6);
    std::fs::remove_file(path).ok();
}

#[test]
fn moe_model_trains() {
    let bundle = quick_bundle();
    let mut cfg = ModelConfig::tiny_moe();
    cfg.d_model = 64;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 96;
    cfg.n_layers = 2;
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(8);
    let mut model = Model::init(&cfg, &mut rng);
    let ppl0 = perplexity(&mut model, &bundle.eval_wiki, 4);
    let tcfg = TrainConfig { steps: 60, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let ppl1 = perplexity(&mut model, &bundle.eval_wiki, 4);
    assert!(ppl1 < ppl0 * 0.5, "moe: {ppl0:.1} -> {ppl1:.1}");
}

#[test]
fn gqa_model_trains() {
    let bundle = quick_bundle();
    let mut cfg = ModelConfig::tiny_gqa();
    cfg.d_model = 64;
    cfg.n_heads = 4;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 96;
    cfg.n_layers = 2;
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(9);
    let mut model = Model::init(&cfg, &mut rng);
    let ppl0 = perplexity(&mut model, &bundle.eval_wiki, 4);
    let tcfg = TrainConfig { steps: 60, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let ppl1 = perplexity(&mut model, &bundle.eval_wiki, 4);
    assert!(ppl1 < ppl0 * 0.5, "gqa: {ppl0:.1} -> {ppl1:.1}");
}
