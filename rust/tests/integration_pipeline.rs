//! Integration: the full Algorithm-1 pipeline on a *trained* model —
//! quantization degrades PPL gracefully, block FT recovers accuracy,
//! end-to-end KD (★) recovers more, and AQLM dominates RTN at matched bits.
//! All methods are named by registry spec strings and routed through the
//! `Quantizer` trait; mixed runs go through `LayerPolicy`.

use aqlm::coordinator::pipeline::{quantize_model, quantize_model_spec};
use aqlm::coordinator::train::{train_native, TrainConfig};
use aqlm::data::dataset::{DataBundle, DataSizes, TokenDataset};
use aqlm::eval::ppl::perplexity;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::e2eft::{e2e_finetune, E2eFtConfig};
use aqlm::quant::spec::{LayerPolicy, MethodSpec};
use aqlm::util::rng::Rng;

struct Setup {
    bundle: DataBundle,
    model: Model,
    calib: Vec<u32>,
    n_seqs: usize,
    seq: usize,
}

fn trained_setup(seed: u64) -> Setup {
    let bundle = DataBundle::generate(
        seed,
        DataSizes { train_tokens: 60_000, eval_tokens: 2_048, calib_tokens: 8_192, seq_len: 48 },
    );
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(seed);
    let mut model = Model::init(&cfg, &mut rng);
    let tcfg = TrainConfig { steps: 200, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let (n_seqs, seq) = (6usize, 48usize);
    let calib = {
        let data = TokenDataset { tokens: bundle.calib.tokens.clone(), seq_len: seq };
        let (c, _) = data.sample_batch(n_seqs, &mut rng);
        c
    };
    Setup { bundle, model, calib, n_seqs, seq }
}

fn spec(s: &str) -> MethodSpec {
    MethodSpec::parse(s).unwrap()
}

#[test]
fn aqlm_with_ft_beats_no_ft_beats_rtn() {
    let s = trained_setup(21);
    let mut rng = Rng::seed_from_u64(1);
    let base_ppl = perplexity(&mut s.model.clone(), &s.bundle.eval_wiki, 8);

    // 1x6g4 ≈ 2.2 bits at nano dims.
    let ft_on = spec("aqlm:1x6,g=4,ft=20,fast");
    let ft_off = spec("aqlm:1x6,g=4,ft=0,fast");

    let mut m_ft = s.model.clone();
    let rep_ft =
        quantize_model_spec(&mut m_ft, &s.calib, s.n_seqs, s.seq, &ft_on, &mut rng).unwrap();
    let ppl_ft = perplexity(&mut m_ft, &s.bundle.eval_wiki, 8);

    let mut m_noft = s.model.clone();
    quantize_model_spec(&mut m_noft, &s.calib, s.n_seqs, s.seq, &ft_off, &mut rng).unwrap();
    let ppl_noft = perplexity(&mut m_noft, &s.bundle.eval_wiki, 8);

    let mut m_rtn = s.model.clone();
    let rep_rtn = quantize_model_spec(
        &mut m_rtn,
        &s.calib,
        s.n_seqs,
        s.seq,
        &spec("rtn:b=2,g=32"), // 3.0 avg bits — closest feasible RTN config above AQLM's 1.9
        &mut rng,
    )
    .unwrap();
    let ppl_rtn = perplexity(&mut m_rtn, &s.bundle.eval_wiki, 8);

    // AQLM uses no more bits than RTN (here it uses strictly fewer —
    // 1.9 vs 3.0 — which makes the PPL ordering below a *stronger* result).
    assert!(
        rep_ft.avg_bits <= rep_rtn.avg_bits + 0.25,
        "budgets: aqlm {} vs rtn {}",
        rep_ft.avg_bits,
        rep_rtn.avg_bits
    );
    // Orderings (the paper's headline): FT ≤ no-FT < RTN; FT close to base.
    assert!(ppl_ft <= ppl_noft * 1.02, "FT hurt: {ppl_ft} vs {ppl_noft}");
    assert!(ppl_noft < ppl_rtn, "AQLM no-FT {ppl_noft} !< RTN {ppl_rtn}");
    assert!(ppl_ft < ppl_rtn, "AQLM FT {ppl_ft} !< RTN {ppl_rtn} (at ~1/3 fewer bits)");
    assert!(ppl_ft < base_ppl * 4.0, "2-bit model unusable: {base_ppl} -> {ppl_ft}");
}

#[test]
fn e2e_kd_improves_quantized_model() {
    let s = trained_setup(22);
    let mut rng = Rng::seed_from_u64(2);
    // Aggressive quantization *without* block FT so the ★ phase has clear
    // headroom (the paper: ★ gains are largest at extreme widths).
    let method = spec("aqlm:1x3,g=8,ft=0,fast"); // brutal: 0.375 code bits/weight
    let mut student = s.model.clone();
    quantize_model_spec(&mut student, &s.calib, s.n_seqs, s.seq, &method, &mut rng).unwrap();
    let ppl_before = perplexity(&mut student, &s.bundle.eval_wiki, 8);
    let mut teacher = s.model.clone();
    let data = TokenDataset { tokens: s.bundle.calib.tokens.clone(), seq_len: s.seq };
    let kl = e2e_finetune(
        &mut student,
        &mut teacher,
        &data,
        E2eFtConfig { steps: 60, batch: 4, lr: 1e-3 },
        &mut rng,
    );
    let ppl_after = perplexity(&mut student, &s.bundle.eval_wiki, 8);
    // The optimized objective (KL to the teacher) must drop clearly...
    let head: f64 = kl[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = kl[kl.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(tail < head * 0.85, "KL did not drop: {head:.4} -> {tail:.4}");
    // ...and perplexity must improve with it.
    assert!(
        ppl_after < ppl_before,
        "★ did not improve PPL: {ppl_before:.3} -> {ppl_after:.3}"
    );
}

#[test]
fn quantized_checkpoint_roundtrip_through_pipeline() {
    let s = trained_setup(23);
    let mut rng = Rng::seed_from_u64(3);
    let method = spec("aqlm:2x5,g=8,ft=4,fast");
    let mut q = s.model.clone();
    let report =
        quantize_model_spec(&mut q, &s.calib, s.n_seqs, s.seq, &method, &mut rng).unwrap();
    let path = std::env::temp_dir().join("aqlm_integration_q.ckpt");
    q.save(&path).unwrap();
    let mut loaded = Model::load(&path).unwrap();
    assert!((loaded.avg_bits() - report.avg_bits).abs() < 1e-6);
    let p1 = perplexity(&mut q, &s.bundle.eval_wiki, 8);
    let p2 = perplexity(&mut loaded, &s.bundle.eval_wiki, 8);
    assert!((p1 - p2).abs() < 1e-9);
    std::fs::remove_file(path).ok();
}

#[test]
fn dense_backed_baselines_keep_size_metadata_through_checkpoint() {
    // QuIP-lite stores dequantized f32 weights; before the per-layer bits
    // table, avg_bits()/weight_bytes() reported FP32 for it after
    // quantization and after save/load. (SpQR left this list when it
    // gained true packed storage — see
    // `packed_spqr_is_structural_and_token_identical` below.)
    let s = trained_setup(24);
    let mut rng = Rng::seed_from_u64(4);
    let m = "quip:b=3,seed=5";
    let mut q = s.model.clone();
    let report =
        quantize_model_spec(&mut q, &s.calib, s.n_seqs, s.seq, &spec(m), &mut rng).unwrap();
    assert!(report.avg_bits < 8.0, "{m}: {}", report.avg_bits);
    assert!(
        (q.avg_bits() - report.avg_bits).abs() < 1e-6,
        "{m}: model reports {} vs pipeline {}",
        q.avg_bits(),
        report.avg_bits
    );
    let dense_bytes = s.model.weight_bytes();
    assert!(q.weight_bytes() < dense_bytes / 2, "{m}: no size win recorded");
    let path = std::env::temp_dir().join(format!("aqlm_integration_{}.ckpt", spec(m).key()));
    q.save(&path).unwrap();
    let loaded = Model::load(&path).unwrap();
    assert!(
        (loaded.avg_bits() - report.avg_bits).abs() < 1e-6,
        "{m}: bits lost across save/load: {}",
        loaded.avg_bits()
    );
    assert_eq!(loaded.weight_bytes(), q.weight_bytes(), "{m}");
    std::fs::remove_file(path).ok();
}

#[test]
fn packed_spqr_is_structural_and_token_identical() {
    // The acceptance bar for the packed SpQR path: quantizing with
    // `spqr:b=3,g=16,out=0.01` must (1) store the packed structure (no
    // dense f32 backing; size accounting independent of the layer_bits
    // fallback), (2) greedily decode token-identically to the previous
    // dense-backed path, and (3) round-trip through a checkpoint with the
    // policy string in the header.
    use aqlm::nn::linear::Linear;
    let s = trained_setup(26);
    let mut rng = Rng::seed_from_u64(6);
    let policy = LayerPolicy::parse("spqr:b=3,g=16,out=0.01").unwrap();
    let mut q = s.model.clone();
    let report = quantize_model(&mut q, &s.calib, s.n_seqs, s.seq, &policy, &mut rng).unwrap();

    // (1) Structural storage: every linear is Linear::Spqr, weight_bytes
    // shrinks accordingly, and clearing the bits table changes nothing —
    // SpQR no longer rides the dense-backed fallback.
    let mut dense_backed = q.clone();
    for (b_q, b_d) in q.blocks.iter().zip(dense_backed.blocks.iter_mut()) {
        for ((name, lin), (_, lin_d)) in b_q.linears().into_iter().zip(b_d.linears_mut()) {
            let Linear::Spqr { q: packed, .. } = lin else {
                panic!("{name}: expected Linear::Spqr, got a different backing");
            };
            *lin_d = Linear::dense(packed.decode());
        }
    }
    assert!((q.avg_bits() - report.avg_bits).abs() < 1e-6);
    let mut no_table = q.clone();
    no_table.layer_bits.clear();
    assert!(
        (no_table.avg_bits() - report.avg_bits).abs() < 1e-6,
        "spqr size accounting still depends on the layer_bits fallback"
    );
    assert!(
        q.weight_bytes() < s.model.weight_bytes() / 2,
        "packed spqr recorded no structural size win"
    );

    // (2) Greedy decode is token-identical to the dense-backed path (the
    // fused kernels are bit-equal to a GEMV over the decoded matrix).
    let prompt = vec![aqlm::data::tokenizer::BOS, 5, 9, 2];
    let toks_packed = q.clone().generate(&prompt, 24, 0.0, &mut Rng::seed_from_u64(0));
    let toks_dense = dense_backed.generate(&prompt, 24, 0.0, &mut Rng::seed_from_u64(0));
    assert_eq!(toks_packed, toks_dense, "packed spqr changed served tokens");

    // (3) Checkpoint round-trip: packed arrays and the policy header.
    assert_eq!(q.quant_policy.as_deref(), Some(policy.to_string().as_str()));
    let path = std::env::temp_dir().join("aqlm_integration_spqr_packed.ckpt");
    q.save(&path).unwrap();
    let mut loaded = Model::load(&path).unwrap();
    assert_eq!(loaded.quant_policy, q.quant_policy);
    assert_eq!(
        LayerPolicy::parse(loaded.quant_policy.as_deref().unwrap()).unwrap(),
        policy,
        "persisted policy no longer parses to what the pipeline ran"
    );
    assert!((loaded.avg_bits() - report.avg_bits).abs() < 1e-6);
    assert_eq!(loaded.weight_bytes(), q.weight_bytes());
    let toks_loaded = loaded.generate(&prompt, 24, 0.0, &mut Rng::seed_from_u64(0));
    assert_eq!(toks_loaded, toks_packed, "checkpoint round-trip changed tokens");
    std::fs::remove_file(path).ok();
}

#[test]
fn mixed_policy_pipeline_on_trained_model() {
    let s = trained_setup(25);
    let mut rng = Rng::seed_from_u64(5);
    // Attention at ~2.2-bit AQLM, MLP at 3-bit RTN — a heterogeneous point.
    let policy = LayerPolicy::parse(
        "*.wq=aqlm:1x6,g=4,ft=0,fast;*.wk=aqlm:1x6,g=4,ft=0,fast;\
         *.wv=aqlm:1x6,g=4,ft=0,fast;*.wo=aqlm:1x6,g=4,ft=0,fast;rtn:b=3,g=32",
    )
    .unwrap();
    let mut m = s.model.clone();
    let report = quantize_model(&mut m, &s.calib, s.n_seqs, s.seq, &policy, &mut rng).unwrap();
    let methods: std::collections::BTreeSet<&str> =
        report.layers.iter().map(|l| l.method.as_str()).collect();
    assert_eq!(methods.into_iter().collect::<Vec<_>>(), vec!["AQLM", "RTN"]);
    assert!((report.avg_bits - m.avg_bits()).abs() < 1e-6);
    // The mixed model still works.
    let ppl = perplexity(&mut m, &s.bundle.eval_wiki, 8);
    let base_ppl = perplexity(&mut s.model.clone(), &s.bundle.eval_wiki, 8);
    assert!(ppl.is_finite() && ppl < base_ppl * 8.0, "mixed model unusable: {ppl}");
}
