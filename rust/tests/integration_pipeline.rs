//! Integration: the full Algorithm-1 pipeline on a *trained* model —
//! quantization degrades PPL gracefully, block FT recovers accuracy,
//! end-to-end KD (★) recovers more, and AQLM dominates RTN at matched bits.

use aqlm::coordinator::pipeline::{quantize_model, Method};
use aqlm::coordinator::train::{train_native, TrainConfig};
use aqlm::data::dataset::{DataBundle, DataSizes, TokenDataset};
use aqlm::eval::ppl::perplexity;
use aqlm::kernels::format::AqlmShape;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::blockft::{BlockFtConfig, FtScope};
use aqlm::quant::aqlm::e2eft::{e2e_finetune, E2eFtConfig};
use aqlm::quant::aqlm::layer::AqlmLayerConfig;
use aqlm::quant::rtn::RtnConfig;
use aqlm::util::rng::Rng;

struct Setup {
    bundle: DataBundle,
    model: Model,
    calib: Vec<u32>,
    n_seqs: usize,
    seq: usize,
}

fn trained_setup(seed: u64) -> Setup {
    let bundle = DataBundle::generate(
        seed,
        DataSizes { train_tokens: 60_000, eval_tokens: 2_048, calib_tokens: 8_192, seq_len: 48 },
    );
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = bundle.tokenizer.padded_vocab_size(16);
    let mut rng = Rng::seed_from_u64(seed);
    let mut model = Model::init(&cfg, &mut rng);
    let tcfg = TrainConfig { steps: 200, batch: 4, seq: 48, lr: 3e-3, log_every: 1000 };
    train_native(&mut model, &bundle.train, tcfg, &mut rng, false);
    let (n_seqs, seq) = (6usize, 48usize);
    let calib = {
        let data = TokenDataset { tokens: bundle.calib.tokens.clone(), seq_len: seq };
        let (c, _) = data.sample_batch(n_seqs, &mut rng);
        c
    };
    Setup { bundle, model, calib, n_seqs, seq }
}

#[test]
fn aqlm_with_ft_beats_no_ft_beats_rtn() {
    let s = trained_setup(21);
    let mut rng = Rng::seed_from_u64(1);
    let base_ppl = perplexity(&mut s.model.clone(), &s.bundle.eval_wiki, 8);

    let shape = AqlmShape::new(1, 6, 4); // ~2.2 bits at nano dims
    let ft_on = Method::Aqlm {
        layer: AqlmLayerConfig::fast(shape),
        block_ft: BlockFtConfig { steps: 20, lr: 1e-3, tol: 0.0, scope: FtScope::Full },
    };
    let ft_off = Method::Aqlm {
        layer: AqlmLayerConfig::fast(shape),
        block_ft: BlockFtConfig { steps: 0, lr: 1e-3, tol: 0.0, scope: FtScope::None },
    };

    let mut m_ft = s.model.clone();
    let rep_ft = quantize_model(&mut m_ft, &s.calib, s.n_seqs, s.seq, &ft_on, &mut rng).unwrap();
    let ppl_ft = perplexity(&mut m_ft, &s.bundle.eval_wiki, 8);

    let mut m_noft = s.model.clone();
    quantize_model(&mut m_noft, &s.calib, s.n_seqs, s.seq, &ft_off, &mut rng).unwrap();
    let ppl_noft = perplexity(&mut m_noft, &s.bundle.eval_wiki, 8);

    let mut m_rtn = s.model.clone();
    let rep_rtn = quantize_model(
        &mut m_rtn,
        &s.calib,
        s.n_seqs,
        s.seq,
        &Method::Rtn(RtnConfig::new(2, 32)), // 3.0 avg bits — closest feasible RTN config above AQLM's 1.9
        &mut rng,
    )
    .unwrap();
    let ppl_rtn = perplexity(&mut m_rtn, &s.bundle.eval_wiki, 8);

    // AQLM uses no more bits than RTN (here it uses strictly fewer —
    // 1.9 vs 4.0 — which makes the PPL ordering below a *stronger* result).
    assert!(
        rep_ft.avg_bits <= rep_rtn.avg_bits + 0.25,
        "budgets: aqlm {} vs rtn {}",
        rep_ft.avg_bits,
        rep_rtn.avg_bits
    );
    // Orderings (the paper's headline): FT ≤ no-FT < RTN; FT close to base.
    assert!(ppl_ft <= ppl_noft * 1.02, "FT hurt: {ppl_ft} vs {ppl_noft}");
    assert!(ppl_noft < ppl_rtn, "AQLM no-FT {ppl_noft} !< RTN {ppl_rtn}");
    assert!(ppl_ft < ppl_rtn, "AQLM FT {ppl_ft} !< RTN {ppl_rtn} (at ~1/3 fewer bits)");
    assert!(ppl_ft < base_ppl * 4.0, "2-bit model unusable: {base_ppl} -> {ppl_ft}");
}

#[test]
fn e2e_kd_improves_quantized_model() {
    let s = trained_setup(22);
    let mut rng = Rng::seed_from_u64(2);
    // Aggressive quantization *without* block FT so the ★ phase has clear
    // headroom (the paper: ★ gains are largest at extreme widths).
    let shape = AqlmShape::new(1, 3, 8); // brutal: 0.375 code bits/weight
    let method = Method::Aqlm {
        layer: AqlmLayerConfig::fast(shape),
        block_ft: BlockFtConfig { steps: 0, lr: 1e-3, tol: 0.0, scope: FtScope::None },
    };
    let mut student = s.model.clone();
    quantize_model(&mut student, &s.calib, s.n_seqs, s.seq, &method, &mut rng).unwrap();
    let ppl_before = perplexity(&mut student, &s.bundle.eval_wiki, 8);
    let mut teacher = s.model.clone();
    let data = TokenDataset { tokens: s.bundle.calib.tokens.clone(), seq_len: s.seq };
    let kl = e2e_finetune(
        &mut student,
        &mut teacher,
        &data,
        E2eFtConfig { steps: 60, batch: 4, lr: 1e-3 },
        &mut rng,
    );
    let ppl_after = perplexity(&mut student, &s.bundle.eval_wiki, 8);
    // The optimized objective (KL to the teacher) must drop clearly...
    let head: f64 = kl[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = kl[kl.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(tail < head * 0.85, "KL did not drop: {head:.4} -> {tail:.4}");
    // ...and perplexity must improve with it.
    assert!(
        ppl_after < ppl_before,
        "★ did not improve PPL: {ppl_before:.3} -> {ppl_after:.3}"
    );
}

#[test]
fn quantized_checkpoint_roundtrip_through_pipeline() {
    let s = trained_setup(23);
    let mut rng = Rng::seed_from_u64(3);
    let method = Method::Aqlm {
        layer: AqlmLayerConfig::fast(AqlmShape::new(2, 5, 8)),
        block_ft: BlockFtConfig { steps: 4, lr: 1e-3, tol: 0.0, scope: FtScope::Full },
    };
    let mut q = s.model.clone();
    let report = quantize_model(&mut q, &s.calib, s.n_seqs, s.seq, &method, &mut rng).unwrap();
    let path = std::env::temp_dir().join("aqlm_integration_q.ckpt");
    q.save(&path).unwrap();
    let mut loaded = Model::load(&path).unwrap();
    assert!((loaded.avg_bits() - report.avg_bits).abs() < 1e-6);
    let p1 = perplexity(&mut q, &s.bundle.eval_wiki, 8);
    let p2 = perplexity(&mut loaded, &s.bundle.eval_wiki, 8);
    assert!((p1 - p2).abs() < 1e-9);
    std::fs::remove_file(path).ok();
}
