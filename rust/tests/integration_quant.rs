//! Integration: the quantization method ordering the paper reports must
//! hold end-to-end on real (trained-ish) layer statistics — AQLM beats the
//! scalar baselines at matched bits, and the shape-search lands budgets.

use aqlm::coordinator::shapes::{choose_shape, model_avg_bits, quantizable_layer_dims};
use aqlm::kernels::format::AqlmShape;
use aqlm::nn::config::ModelConfig;
use aqlm::quant::aqlm::layer::{AqlmLayerConfig, LayerQuantizer};
use aqlm::quant::gptq::{gptq_quantize, GptqConfig};
use aqlm::quant::quip::{quip_quantize, QuipConfig};
use aqlm::quant::rtn::{rtn_quantize, RtnConfig};
use aqlm::quant::spqr::{spqr_quantize, SpqrConfig};
use aqlm::quant::{relative_layer_error, CalibData};
use aqlm::tensor::Tensor;
use aqlm::util::rng::Rng;

/// Correlated activations + structured weights: a harder, more realistic
/// test bed than iid Gaussians.
fn setup(d_out: usize, d_in: usize, seed: u64) -> (Tensor, CalibData, Rng) {
    let mut rng = Rng::seed_from_u64(seed);
    // Low-rank + noise weights (real layers are far from isotropic).
    let u = Tensor::randn(&[d_out, 8], 0.5, &mut rng);
    let v = Tensor::randn(&[8, d_in], 0.5, &mut rng);
    let mut w = aqlm::tensor::ops::matmul(&u, &v);
    let noise = Tensor::randn(&[d_out, d_in], 0.15, &mut rng);
    w.add_assign(&noise);
    // Activations with channel-dependent scale.
    let mut x = Tensor::zeros(&[512, d_in]);
    for i in 0..512 {
        for j in 0..d_in {
            let scale = 0.1 + 2.0 * ((j * 7 % d_in) as f32 / d_in as f32);
            let val = rng.normal_f32(0.0, scale);
            x.set2(i, j, val);
        }
    }
    let mut calib = CalibData::new(d_in);
    calib.accumulate(&x);
    (w, calib, rng)
}

#[test]
fn method_ordering_at_2bits() {
    let (w, calib, mut rng) = setup(96, 96, 1);
    // ~2-bit budget for every method: per-row scales all around so RTN and
    // GPTQ differ only in data-awareness + error feedback.
    let e_rtn = relative_layer_error(&w, &rtn_quantize(&w, RtnConfig::new(2, 96)).decode(), &calib);
    let e_gptq = relative_layer_error(
        &w,
        &gptq_quantize(&w, &calib, GptqConfig::paper(2)).unwrap().decode(),
        &calib,
    );
    let e_quip = relative_layer_error(
        &w,
        &quip_quantize(&w, &calib, QuipConfig { bits: 2, seed: 3 }).unwrap().dense,
        &calib,
    );
    let shape = AqlmShape::new(1, 8, 4); // 2 bits codes + overhead
    let (q, _) = LayerQuantizer::new(AqlmLayerConfig::new(shape)).quantize(&w, &calib, &mut rng);
    let e_aqlm = relative_layer_error(&w, &q.decode(), &calib);

    // The paper's ordering at extreme compression.
    assert!(e_gptq < e_rtn, "gptq {e_gptq} !< rtn {e_rtn}");
    assert!(e_aqlm < e_gptq, "aqlm {e_aqlm} !< gptq {e_gptq}");
    assert!(e_aqlm < e_quip, "aqlm {e_aqlm} !< quip {e_quip}");
}

#[test]
fn spqr_between_gptq_and_aqlm_with_outliers() {
    let (mut w, calib, mut rng) = setup(64, 64, 2);
    for _ in 0..30 {
        let i = rng.below(64);
        let j = rng.below(64);
        w.set2(i, j, 8.0);
    }
    let e_gptq = relative_layer_error(
        &w,
        &gptq_quantize(&w, &calib, GptqConfig::grouped(3, 16)).unwrap().decode(),
        &calib,
    );
    let e_spqr = relative_layer_error(
        &w,
        &spqr_quantize(&w, &calib, SpqrConfig { bits: 3, group: 16, outlier_frac: 0.02 })
            .unwrap()
            .decode(),
        &calib,
    );
    assert!(e_spqr < e_gptq, "spqr {e_spqr} !< gptq {e_gptq}");
}

#[test]
fn aqlm_bits_error_tradeoff_monotone() {
    let (w, calib, mut rng) = setup(64, 64, 3);
    let mut errors = Vec::new();
    for shape in [AqlmShape::new(1, 6, 8), AqlmShape::new(1, 8, 4), AqlmShape::new(2, 8, 4)] {
        let (q, _) =
            LayerQuantizer::new(AqlmLayerConfig::fast(shape)).quantize(&w, &calib, &mut rng);
        errors.push((q.avg_bits(), relative_layer_error(&w, &q.decode(), &calib)));
    }
    errors.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // More bits → less error across the ladder.
    for pair in errors.windows(2) {
        assert!(
            pair[1].1 <= pair[0].1 * 1.1,
            "non-monotone bits/error: {:?}",
            errors
        );
    }
}

#[test]
fn shape_search_budgets_all_presets() {
    for preset in ["nano", "tiny", "small", "tiny-gqa", "tiny-moe"] {
        let cfg = ModelConfig::preset(preset).unwrap();
        let dims = quantizable_layer_dims(&cfg);
        for target in [2.0f64, 2.5, 3.0, 4.0] {
            let shape = choose_shape(&cfg, target, 8);
            let got = model_avg_bits(shape, &dims);
            assert!(
                (got - target).abs() < 0.6,
                "{preset}@{target}: {} -> {got:.3}",
                shape.name()
            );
        }
    }
}

#[test]
fn calibration_awareness_matters() {
    // AQLM optimized against the true XXᵀ must beat AQLM optimized against
    // identity when evaluated on the true output error — the paper's
    // "instance-aware" innovation (1).
    let (w, calib, mut rng) = setup(64, 64, 4);
    let shape = AqlmShape::new(1, 6, 4);
    let (q_aware, _) =
        LayerQuantizer::new(AqlmLayerConfig::new(shape)).quantize(&w, &calib, &mut rng);
    let identity = CalibData::identity(64);
    let (q_blind, _) =
        LayerQuantizer::new(AqlmLayerConfig::new(shape)).quantize(&w, &identity, &mut rng);
    let e_aware = relative_layer_error(&w, &q_aware.decode(), &calib);
    let e_blind = relative_layer_error(&w, &q_blind.decode(), &calib);
    assert!(e_aware < e_blind, "aware {e_aware} !< blind {e_blind}");
}
