//! Integration over the PJRT runtime: the AOT artifacts produced by
//! `python/compile/aot.py` must load, execute, agree with the native Rust
//! engine, and train. Requires `make artifacts` to have run (the Makefile
//! test target guarantees it).

use aqlm::nn::config::ModelConfig;
use aqlm::nn::model::Model;
use aqlm::runtime::artifacts::Manifest;
use aqlm::runtime::engine::{PjrtForward, PjrtTrainer};
use aqlm::runtime::pjrt::{HostTensor, PjrtRuntime};
use aqlm::util::rng::Rng;
use std::path::Path;

fn manifest() -> Manifest {
    Manifest::load(Path::new("artifacts"))
        .expect("artifacts/manifest.json missing — run `make artifacts` first")
}

fn nano_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::nano();
    cfg.vocab_size = 160; // matches the lowered artifact
    let mut rng = Rng::seed_from_u64(seed);
    Model::init(&cfg, &mut rng)
}

#[test]
fn pjrt_forward_matches_native_logits() {
    let m = manifest();
    let rt = PjrtRuntime::cpu().unwrap();
    let fwd = PjrtForward::load(&rt, &m, "nano").unwrap();
    let mut model = nano_model(1);
    let mut rng = Rng::seed_from_u64(2);
    let tokens: Vec<u32> = (0..fwd.batch * fwd.seq).map(|_| rng.below(160) as u32).collect();
    let pjrt_logits = fwd.logits(&model, &tokens).unwrap();
    let (native, _) = model.forward_logits(&tokens, fwd.batch, fwd.seq, false);
    assert_eq!(native.shape(), pjrt_logits.shape());
    let max_diff = native
        .data()
        .iter()
        .zip(pjrt_logits.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 2e-2,
        "native Rust forward and AOT JAX forward disagree: max diff {max_diff}"
    );
}

#[test]
fn pjrt_train_step_reduces_loss() {
    let m = manifest();
    let rt = PjrtRuntime::cpu().unwrap();
    let model = nano_model(3);
    let mut trainer = PjrtTrainer::new(&rt, &m, "nano", &model).unwrap();
    let mut rng = Rng::seed_from_u64(4);
    // A learnable repeating pattern.
    let pattern: Vec<u32> = (0..trainer.batch * trainer.seq).map(|i| (i % 7) as u32).collect();
    let targets: Vec<u32> =
        (0..trainer.batch * trainer.seq).map(|i| ((i + 1) % 7) as u32).collect();
    let first = trainer.step(&pattern, &targets).unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = trainer.step(&pattern, &targets).unwrap();
    }
    assert!(last < first * 0.8, "pjrt training: {first:.4} -> {last:.4}");
    let _ = rng.next_u64();
    // Export back to a native model and verify the loss transfer.
    let mut out = nano_model(99);
    trainer.export_into(&mut out).unwrap();
    let (logits, _) = out.forward_logits(&pattern, trainer.batch, trainer.seq, false);
    let native_loss = aqlm::nn::loss::cross_entropy_loss_only(&logits, &targets);
    assert!(
        (native_loss - last).abs() < 0.15,
        "exported params do not reproduce pjrt loss: {native_loss:.4} vs {last:.4}"
    );
}

#[test]
fn pallas_kernel_artifact_matches_rust_kernels() {
    let m = manifest();
    let rt = PjrtRuntime::cpu().unwrap();
    let spec = m.module("aqlm_gemm_2x256g8").unwrap();
    let module = rt.compile(spec).unwrap();
    // Build matching Rust-side weights from the manifest's shapes.
    let (n, d_in) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let d_out = spec.inputs[1].shape[0];
    let m_cnt = spec.inputs[1].shape[2];
    let k = spec.inputs[2].shape[1];
    let g = spec.inputs[2].shape[2];
    let mut rng = Rng::seed_from_u64(5);
    let shape = aqlm::kernels::format::AqlmShape::new(m_cnt, (k as f64).log2() as usize, g);
    let w = aqlm::bench::kernels::synthetic_weight(d_out, d_in, shape, &mut rng);
    let x: Vec<f32> = (0..n * d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    // PJRT execution of the Pallas (interpret) kernel.
    let codes_i32: Vec<i32> = w.codes.iter().map(|&c| c as i32).collect();
    let mut codebooks_flat: Vec<f32> = Vec::new();
    for cb in &w.codebooks {
        codebooks_flat.extend_from_slice(cb.data());
    }
    let outputs = module
        .run(&[
            HostTensor::f32(x.clone(), &[n, d_in]),
            HostTensor::i32(codes_i32, &[d_out, d_in / g, m_cnt]),
            HostTensor::f32(codebooks_flat, &[m_cnt, k, g]),
            HostTensor::f32(w.scales.clone(), &[d_out]),
        ])
        .unwrap();
    let pallas_y = outputs[0].as_f32().unwrap();

    // Rust LUT kernel, row by row of the batch.
    let packed = aqlm::kernels::matvec::PackedAqlm::from_weight(&w);
    let mut lut = vec![0.0f32; packed.lut_len()];
    let mut y = vec![0.0f32; d_out];
    for row in 0..n {
        packed.matvec_lut(&x[row * d_in..(row + 1) * d_in], &mut lut, &mut y);
        for c in 0..d_out {
            let p = pallas_y[row * d_out + c];
            assert!(
                (p - y[c]).abs() < 1e-3 * (1.0 + p.abs()),
                "pallas vs rust kernel mismatch at ({row},{c}): {p} vs {}",
                y[c]
            );
        }
    }
}
