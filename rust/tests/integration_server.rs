//! Integration over the serving path: concurrency, conservation, and
//! quantized-model serving correctness.

use aqlm::coordinator::server::{Server, ServerConfig};
use aqlm::kernels::format::AqlmShape;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::linear::Linear;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::layer::{AqlmLayerConfig, LayerQuantizer};
use aqlm::quant::CalibData;
use aqlm::util::rng::Rng;

fn model(seed: u64) -> Model {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab_size = 64;
    cfg.max_seq = 48;
    Model::init(&cfg, &mut Rng::seed_from_u64(seed))
}

#[test]
fn many_clients_all_served_exactly_once() {
    let server = Server::start(model(1), ServerConfig { max_batch: 4, seed: 0, ..Default::default() });
    let n = 24;
    // Submit from multiple client threads to exercise the channel path.
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    let (res_tx, res_rx) = std::sync::mpsc::channel();
    for t in 0..3 {
        let res_tx = res_tx.clone();
        let rxs: Vec<_> = (0..n / 3)
            .map(|i| server.submit(vec![1 + (t * 8 + i) as u32 % 60], 3 + i % 5, 0.0))
            .collect();
        handles.push(std::thread::spawn(move || {
            for rx in rxs {
                let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                res_tx.send(resp.generated).unwrap();
            }
        }));
    }
    drop(res_tx);
    for h in handles {
        h.join().unwrap();
    }
    let served: Vec<usize> = res_rx.iter().collect();
    assert_eq!(served.len(), n);
    let server = std::sync::Arc::try_unwrap(server).ok().unwrap();
    let stats = server.shutdown();
    assert_eq!(stats.requests, n);
    assert_eq!(stats.tokens_generated, served.iter().sum::<usize>());
}

#[test]
fn quantized_model_serves_same_greedy_tokens_as_offline() {
    // Quantize every linear, then check server greedy output == offline
    // generate on the same quantized model (kernel paths agree).
    let mut m = model(2);
    let mut rng = Rng::seed_from_u64(3);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(AqlmShape::new(2, 5, 4)));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let mut offline = m.clone();
    let expected = offline.generate(&[5, 9, 2], 8, 0.0, &mut Rng::seed_from_u64(0));
    let server = Server::start(m, ServerConfig::default());
    let resp = server.submit(vec![5, 9, 2], 8, 0.0).recv().unwrap();
    assert_eq!(resp.tokens, expected);
    server.shutdown();
}

#[test]
fn spqr_model_serves_same_greedy_tokens_as_offline() {
    // Same parity bar for the packed sparse-outlier path: server greedy
    // output through the fused SpQR matvec/matvec_batch kernels must equal
    // offline generate on the same packed model.
    use aqlm::quant::spqr::{spqr_quantize, SpqrConfig};
    let mut m = model(4);
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let q = spqr_quantize(&w, &calib, SpqrConfig { bits: 3, group: 16, outlier_frac: 0.02 })
                .unwrap();
            *lin = Linear::spqr(q);
        }
    }
    let mut offline = m.clone();
    let expected = offline.generate(&[5, 9, 2], 8, 0.0, &mut Rng::seed_from_u64(0));
    let server = Server::start(m, ServerConfig::default());
    let resp = server.submit(vec![5, 9, 2], 8, 0.0).recv().unwrap();
    assert_eq!(resp.tokens, expected);
    server.shutdown();
}

#[test]
fn quantized_batched_decode_matches_offline_for_concurrent_sequences() {
    // The batched decode path (one matmat per layer for all active
    // sequences) must reproduce the single-sequence offline output
    // token-for-token, per sequence, when several quantized-kernel
    // sequences are in flight at once.
    let mut m = model(5);
    let mut rng = Rng::seed_from_u64(6);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(AqlmShape::new(2, 5, 4)));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let prompts: Vec<Vec<u32>> = vec![vec![5, 9, 2], vec![13], vec![40, 3], vec![7, 7, 7, 7]];
    let mut offline = m.clone();
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| offline.generate(p, 8, 0.0, &mut Rng::seed_from_u64(0)))
        .collect();
    let server = Server::start(m, ServerConfig { max_batch: 4, seed: 0, ..Default::default() });
    let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 8, 0.0)).collect();
    for (rx, want) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(&resp.tokens, want, "batched quantized decode diverged from offline");
    }
    server.shutdown();
}

#[test]
fn kv_pressure_server_completes_all_requests_token_identically() {
    // Per-worker pool of 28 blocks × 4 positions (2 layers) = 56 positions
    // per sequence max, but 6 × 11-position requests demand 36 blocks of
    // steady-state KV — more than the pool when all run at once. Admission
    // must hold requests back (or preempt) and still serve every request
    // with exactly the offline greedy tokens.
    let mut offline = model(7);
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| vec![5 + i as u32, 9, 2]).collect();
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| offline.generate(p, 8, 0.0, &mut Rng::seed_from_u64(0)))
        .collect();
    let cfg = ServerConfig {
        max_batch: 6,
        kv_block_size: 4,
        kv_pool_blocks: Some(28),
        ..Default::default()
    };
    let server = Server::start(offline, cfg);
    let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 8, 0.0)).collect();
    for (rx, want) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(&resp.tokens, want, "KV pressure changed greedy output");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 6);
}

#[test]
fn paged_pool_admits_more_concurrency_than_contiguous_at_same_memory() {
    // Drive a WorkerScheduler synchronously (deterministic, no threads).
    // 28 blocks × 4 positions: a contiguous cache of the same memory
    // reserves 2 layers × 48 positions = 24 blocks per sequence, so it
    // admits exactly 1 sequence. The paged scheduler admits all 4 short
    // requests at once, and each still matches offline greedy decoding.
    use aqlm::coordinator::scheduler::{
        prompt_window, AdmissionQueue, GenRequest, SchedConfig, WorkerScheduler,
    };
    let mut m = model(8);
    let prompts: Vec<Vec<u32>> =
        vec![vec![5, 9, 2], vec![13, 1, 1], vec![40, 3, 2], vec![7, 7, 7]];
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| m.generate(p, 8, 0.0, &mut Rng::seed_from_u64(0)))
        .collect();
    m.warm_decode();
    let contiguous_blocks_per_seq = m.cfg.n_layers * m.cfg.max_seq.div_ceil(4);
    let n_blocks = 28;
    assert_eq!(n_blocks / contiguous_blocks_per_seq, 1, "contiguous admits exactly 1");
    let pool = m.new_kv_pool(4, n_blocks);
    let cfg = SchedConfig {
        max_batch: 4,
        prefill_chunk: 32,
        window: prompt_window(m.cfg.max_seq, (n_blocks / m.cfg.n_layers) * 4),
        decode_cap: m.cfg.max_seq,
        vocab: m.cfg.vocab_size,
    };
    let mut sched = WorkerScheduler::new(cfg, pool, m.cfg.n_layers);
    let mut queue = AdmissionQueue::new();
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = std::sync::mpsc::channel();
        rxs.push(rx);
        let req = GenRequest {
            prompt: p.clone(),
            max_new: 8,
            temperature: 0.0,
            priority: 0,
            deadline: None,
            model: None,
            respond: tx,
            stream: None,
        };
        queue.push_new(req, i as u64);
    }
    let mut rng = Rng::seed_from_u64(0);
    let mut scratch = Vec::new();
    let mut peak = 0;
    let mut guard = 0;
    while !queue.is_empty() || sched.has_work() {
        while sched.active_len() < cfg.max_batch {
            match queue.peek() {
                Some(q) if sched.can_admit(q) => {
                    let q = queue.pop().unwrap();
                    let _ = sched.admit(q);
                }
                _ => break,
            }
        }
        peak = peak.max(sched.active_len());
        let (_done, requeues) = sched.step(&m, &mut rng, &mut scratch);
        for q in requeues {
            queue.push_back(q);
        }
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    assert!(
        peak > 1,
        "paged pool must admit more concurrent sequences ({peak}) than contiguous (1)"
    );
    for (rx, want) in rxs.iter().zip(&expected) {
        let resp = rx.try_recv().expect("request completed");
        assert_eq!(&resp.tokens, want, "paged concurrent decode diverged from offline");
    }
}

#[test]
fn multi_worker_server_passes_conservation_and_parity() {
    // The whole-suite bar for replicas: with 2 workers, every request is
    // answered exactly once and greedy output still matches the offline
    // single-sequence result regardless of which worker served it.
    let mut offline = model(9);
    let prompts: Vec<Vec<u32>> = (0..12).map(|i| vec![1 + i as u32 % 60, 4]).collect();
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| offline.generate(p, 6, 0.0, &mut Rng::seed_from_u64(0)))
        .collect();
    let cfg = ServerConfig { workers: 2, max_batch: 3, ..Default::default() };
    let server = Server::start(offline, cfg);
    let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 6, 0.0)).collect();
    for (rx, want) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(&resp.tokens, want, "multi-worker greedy diverged from offline");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.per_worker_requests.len(), 2);
    assert_eq!(stats.per_worker_requests.iter().sum::<usize>(), 12);
}

#[test]
fn prompt_at_pool_capacity_is_truncated_to_pool_window() {
    // The admission window must follow the *pool* when it is tighter than
    // the model context: 12 blocks × 4 positions over 2 layers hold 24
    // positions per sequence, so a 24-token prompt (== pool capacity,
    // < max_seq = 48) must be truncated to 23 and still generate.
    let cfg = ServerConfig {
        max_batch: 2,
        kv_block_size: 4,
        kv_pool_blocks: Some(12),
        ..Default::default()
    };
    let server = Server::start(model(10), cfg);
    let prompt: Vec<u32> = (0..24).map(|i| 1 + i % 60).collect();
    let resp = server
        .submit(prompt.clone(), 4, 0.0)
        .recv_timeout(std::time::Duration::from_secs(60))
        .unwrap();
    assert!(resp.generated >= 1, "pool-clamped prompt must still generate");
    assert!(resp.tokens.len() <= 24, "response must fit the pool's per-sequence capacity");
    let kept = resp.tokens.len() - resp.generated;
    assert_eq!(&resp.tokens[..kept], &prompt[prompt.len() - kept..], "keeps the prompt tail");
    let stats = server.shutdown();
    assert_eq!(stats.requests, 1);
}

#[test]
fn quantized_kv_multi_worker_matches_offline_at_every_width() {
    // Quantized-KV determinism across replicas: with 2 workers and the KV
    // cache stored at 8/4/3 bits, every request must reproduce the offline
    // `generate_with_kv_bits` oracle token-for-token — and a second server
    // instance must reproduce the same outputs (no run-to-run drift from
    // worker scheduling).
    use aqlm::nn::kvcache::KvBits;
    for kvb in [KvBits::B8, KvBits::B4, KvBits::B3] {
        let mut offline = model(12);
        let prompts: Vec<Vec<u32>> = (0..10).map(|i| vec![1 + i as u32 % 60, 4, 9]).collect();
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| offline.generate_with_kv_bits(p, 6, 0.0, &mut Rng::seed_from_u64(0), kvb))
            .collect();
        for run in 0..2 {
            let cfg = ServerConfig { workers: 2, max_batch: 3, kv_bits: kvb, ..Default::default() };
            let server = Server::start(offline.clone(), cfg);
            let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 6, 0.0)).collect();
            for (rx, want) in rxs.into_iter().zip(&expected) {
                let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                assert_eq!(
                    &resp.tokens,
                    want,
                    "kv={} run={run}: multi-worker quantized KV diverged from offline",
                    kvb.label()
                );
            }
            let stats = server.shutdown();
            assert_eq!(stats.requests, 10);
            assert_eq!(stats.per_worker_requests.len(), 2);
        }
    }
}

#[test]
fn kv4_preemption_restart_is_bit_exact() {
    // Preemption under a 4-bit KV cache must be invisible in the output:
    // drive a WorkerScheduler synchronously with a pool too small for all
    // four sequences' steady-state KV, so some are preempted mid-decode and
    // restarted — every request must still match the non-preempted offline
    // oracle at the same kv_bits, bit for bit.
    use aqlm::coordinator::scheduler::{
        prompt_window, AdmissionQueue, GenRequest, SchedConfig, WorkerScheduler,
    };
    use aqlm::nn::kvcache::KvBits;
    let mut m = model(11);
    let prompts: Vec<Vec<u32>> =
        vec![vec![5, 9, 2], vec![13, 1, 1], vec![40, 3, 2], vec![7, 7, 7]];
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| m.generate_with_kv_bits(p, 8, 0.0, &mut Rng::seed_from_u64(0), KvBits::B4))
        .collect();
    m.warm_decode();
    // 14 blocks × 4 positions over 2 layers: admission reserves prompt+1
    // (2 blocks/seq), so all four are admitted, but steady state wants
    // 4 seqs × 2 layers × 3 blocks = 24 > 14 — growth must preempt.
    let n_blocks = 14;
    let pool = m.new_kv_pool_with(4, n_blocks, KvBits::B4);
    let cfg = SchedConfig {
        max_batch: 4,
        prefill_chunk: 32,
        window: prompt_window(m.cfg.max_seq, (n_blocks / m.cfg.n_layers) * 4),
        decode_cap: (n_blocks / m.cfg.n_layers) * 4,
        vocab: m.cfg.vocab_size,
    };
    let mut sched = WorkerScheduler::new(cfg, pool, m.cfg.n_layers);
    let mut queue = AdmissionQueue::new();
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = std::sync::mpsc::channel();
        rxs.push(rx);
        let req = GenRequest {
            prompt: p.clone(),
            max_new: 8,
            temperature: 0.0,
            priority: 0,
            deadline: None,
            model: None,
            respond: tx,
            stream: None,
        };
        queue.push_new(req, i as u64);
    }
    let mut rng = Rng::seed_from_u64(0);
    let mut scratch = Vec::new();
    let mut preemptions = 0;
    let mut guard = 0;
    while !queue.is_empty() || sched.has_work() {
        while sched.active_len() < cfg.max_batch {
            match queue.peek() {
                Some(q) if sched.can_admit(q) => {
                    let q = queue.pop().unwrap();
                    let _ = sched.admit(q);
                }
                _ => break,
            }
        }
        let (_done, requeues) = sched.step(&m, &mut rng, &mut scratch);
        preemptions += requeues.len();
        for q in requeues {
            queue.push_back(q);
        }
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    assert!(preemptions > 0, "undersized kv4 pool must force preemption");
    for (rx, want) in rxs.iter().zip(&expected) {
        let resp = rx.try_recv().expect("request completed");
        assert!(!resp.cancelled);
        assert_eq!(&resp.tokens, want, "kv4 preemption restart changed greedy output");
    }
}

#[test]
fn quantized_kv_output_is_invariant_across_threads_and_workers() {
    // The knob-invariance bar extends to every KV width: kernel threads
    // {1, 2} × workers {1, 2} must produce identical tokens at each
    // kv_bits, matching the offline oracle. (The SIMD axis is covered by
    // CI re-running this suite under AQLM_NO_SIMD=1.)
    use aqlm::kernels::config::KernelConfig;
    use aqlm::nn::kvcache::KvBits;
    let base = model(13);
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| vec![2 + i as u32 * 7 % 60, 11]).collect();
    for kvb in KvBits::ALL {
        let mut offline = base.clone();
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| offline.generate_with_kv_bits(p, 6, 0.0, &mut Rng::seed_from_u64(0), kvb))
            .collect();
        for workers in [1usize, 2] {
            for threads in [1usize, 2] {
                let cfg = ServerConfig {
                    workers,
                    max_batch: 3,
                    kv_bits: kvb,
                    kernel: KernelConfig { threads, simd: true },
                    ..Default::default()
                };
                let server = Server::start(offline.clone(), cfg);
                let rxs: Vec<_> =
                    prompts.iter().map(|p| server.submit(p.clone(), 6, 0.0)).collect();
                for (rx, want) in rxs.into_iter().zip(&expected) {
                    let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                    assert_eq!(
                        &resp.tokens,
                        want,
                        "kv={} workers={workers} threads={threads}: knob changed output",
                        kvb.label()
                    );
                }
                server.shutdown();
            }
        }
    }
}

#[test]
fn interleaving_requests_do_not_corrupt_each_other() {
    // Two identical prompts submitted with other traffic in between must
    // produce identical greedy outputs (KV caches are isolated).
    let server = Server::start(model(4), ServerConfig { max_batch: 3, seed: 0, ..Default::default() });
    let rx1 = server.submit(vec![7, 7, 7], 6, 0.0);
    let _noise: Vec<_> = (0..5).map(|i| server.submit(vec![i as u32 + 1], 4, 0.0)).collect();
    let rx2 = server.submit(vec![7, 7, 7], 6, 0.0);
    let a = rx1.recv().unwrap().tokens;
    let b = rx2.recv().unwrap().tokens;
    assert_eq!(a, b, "interleaved identical prompts diverged");
    server.shutdown();
}
