//! Integration over the serving path: concurrency, conservation, and
//! quantized-model serving correctness.

use aqlm::coordinator::server::{Server, ServerConfig};
use aqlm::kernels::format::AqlmShape;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::linear::Linear;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::layer::{AqlmLayerConfig, LayerQuantizer};
use aqlm::quant::CalibData;
use aqlm::util::rng::Rng;

fn model(seed: u64) -> Model {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab_size = 64;
    cfg.max_seq = 48;
    Model::init(&cfg, &mut Rng::seed_from_u64(seed))
}

#[test]
fn many_clients_all_served_exactly_once() {
    let server = Server::start(model(1), ServerConfig { max_batch: 4, seed: 0 });
    let n = 24;
    // Submit from multiple client threads to exercise the channel path.
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    let (res_tx, res_rx) = std::sync::mpsc::channel();
    for t in 0..3 {
        let res_tx = res_tx.clone();
        let rxs: Vec<_> = (0..n / 3)
            .map(|i| server.submit(vec![1 + (t * 8 + i) as u32 % 60], 3 + i % 5, 0.0))
            .collect();
        handles.push(std::thread::spawn(move || {
            for rx in rxs {
                let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                res_tx.send(resp.generated).unwrap();
            }
        }));
    }
    drop(res_tx);
    for h in handles {
        h.join().unwrap();
    }
    let served: Vec<usize> = res_rx.iter().collect();
    assert_eq!(served.len(), n);
    let server = std::sync::Arc::try_unwrap(server).ok().unwrap();
    let stats = server.shutdown();
    assert_eq!(stats.requests, n);
    assert_eq!(stats.tokens_generated, served.iter().sum::<usize>());
}

#[test]
fn quantized_model_serves_same_greedy_tokens_as_offline() {
    // Quantize every linear, then check server greedy output == offline
    // generate on the same quantized model (kernel paths agree).
    let mut m = model(2);
    let mut rng = Rng::seed_from_u64(3);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(AqlmShape::new(2, 5, 4)));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let mut offline = m.clone();
    let expected = offline.generate(&[5, 9, 2], 8, 0.0, &mut Rng::seed_from_u64(0));
    let server = Server::start(m, ServerConfig::default());
    let resp = server.submit(vec![5, 9, 2], 8, 0.0).recv().unwrap();
    assert_eq!(resp.tokens, expected);
    server.shutdown();
}

#[test]
fn spqr_model_serves_same_greedy_tokens_as_offline() {
    // Same parity bar for the packed sparse-outlier path: server greedy
    // output through the fused SpQR matvec/matvec_batch kernels must equal
    // offline generate on the same packed model.
    use aqlm::quant::spqr::{spqr_quantize, SpqrConfig};
    let mut m = model(4);
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let q = spqr_quantize(&w, &calib, SpqrConfig { bits: 3, group: 16, outlier_frac: 0.02 })
                .unwrap();
            *lin = Linear::spqr(q);
        }
    }
    let mut offline = m.clone();
    let expected = offline.generate(&[5, 9, 2], 8, 0.0, &mut Rng::seed_from_u64(0));
    let server = Server::start(m, ServerConfig::default());
    let resp = server.submit(vec![5, 9, 2], 8, 0.0).recv().unwrap();
    assert_eq!(resp.tokens, expected);
    server.shutdown();
}

#[test]
fn quantized_batched_decode_matches_offline_for_concurrent_sequences() {
    // The batched decode path (one matmat per layer for all active
    // sequences) must reproduce the single-sequence offline output
    // token-for-token, per sequence, when several quantized-kernel
    // sequences are in flight at once.
    let mut m = model(5);
    let mut rng = Rng::seed_from_u64(6);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(AqlmShape::new(2, 5, 4)));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let prompts: Vec<Vec<u32>> = vec![vec![5, 9, 2], vec![13], vec![40, 3], vec![7, 7, 7, 7]];
    let mut offline = m.clone();
    let expected: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| offline.generate(p, 8, 0.0, &mut Rng::seed_from_u64(0)))
        .collect();
    let server = Server::start(m, ServerConfig { max_batch: 4, seed: 0 });
    let rxs: Vec<_> = prompts.iter().map(|p| server.submit(p.clone(), 8, 0.0)).collect();
    for (rx, want) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(&resp.tokens, want, "batched quantized decode diverged from offline");
    }
    server.shutdown();
}

#[test]
fn interleaving_requests_do_not_corrupt_each_other() {
    // Two identical prompts submitted with other traffic in between must
    // produce identical greedy outputs (KV caches are isolated).
    let server = Server::start(model(4), ServerConfig { max_batch: 3, seed: 0 });
    let rx1 = server.submit(vec![7, 7, 7], 6, 0.0);
    let _noise: Vec<_> = (0..5).map(|i| server.submit(vec![i as u32 + 1], 4, 0.0)).collect();
    let rx2 = server.submit(vec![7, 7, 7], 6, 0.0);
    let a = rx1.recv().unwrap().tokens;
    let b = rx2.recv().unwrap().tokens;
    assert_eq!(a, b, "interleaved identical prompts diverged");
    server.shutdown();
}
