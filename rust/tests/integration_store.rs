//! Integration over the tiered artifact store: byte-accurate lazy
//! loading, v1 eager fallback through the registry, and the acceptance
//! scenario — two quantized variants served under a byte budget smaller
//! than their summed footprint, token-identical to single-model runs,
//! with at least one eviction.

use aqlm::coordinator::server::{Server, ServerConfig, SubmitOpts};
use aqlm::kernels::format::AqlmShape;
use aqlm::nn::config::ModelConfig;
use aqlm::nn::linear::Linear;
use aqlm::nn::model::Model;
use aqlm::quant::aqlm::layer::{AqlmLayerConfig, LayerQuantizer};
use aqlm::quant::CalibData;
use aqlm::runtime::store::{ArtifactFile, LazyModel, ModelRegistry};
use aqlm::util::json::Json;
use aqlm::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn base_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::nano();
    cfg.d_model = 32;
    cfg.n_heads = 2;
    cfg.n_kv_heads = 2;
    cfg.d_ff = 48;
    cfg.vocab_size = 64;
    cfg.max_seq = 48;
    Model::init(&cfg, &mut Rng::seed_from_u64(seed))
}

/// Quantize every linear of a fresh model with AQLM and save it,
/// returning the in-memory model and the checkpoint path.
fn quantized_ckpt(tag: &str, seed: u64, shape: AqlmShape) -> (Model, PathBuf) {
    let mut m = base_model(seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x5eed);
    let lq = LayerQuantizer::new(AqlmLayerConfig::fast(shape));
    for block in &mut m.blocks {
        for (_, lin) in block.linears_mut() {
            let w = lin.weight_owned();
            let calib = CalibData::identity(w.cols());
            let (q, _) = lq.quantize(&w, &calib, &mut rng);
            *lin = Linear::aqlm(q);
        }
    }
    let path = std::env::temp_dir().join(format!("aqlm_itest_store_{tag}.bin"));
    m.save(&path).unwrap();
    (m, path)
}

#[test]
fn lazy_open_reads_only_header_plus_touched_sections() {
    // The store's core byte-accounting claim, checked against the real
    // file size: header + all sections account for every byte on disk,
    // open reads exactly the header, and each touch adds exactly that
    // section's indexed length.
    let (_, path) = quantized_ckpt("accounting", 5, AqlmShape::new(2, 5, 4));
    let file_size = std::fs::metadata(&path).unwrap().len();
    let lm = LazyModel::open(&path).unwrap();
    assert_eq!(
        lm.header_bytes() + lm.total_section_bytes(),
        file_size,
        "section index must account for the whole blob"
    );
    assert_eq!(lm.bytes_read(), lm.header_bytes(), "open must read only the header");

    let mut art = ArtifactFile::open(&path).unwrap();
    let mut expected = lm.header_bytes();
    for name in ["b0.wq", "b0.wd"] {
        expected += art.section_len(name).unwrap() as u64;
        let l = lm.touch_linear(name).unwrap();
        assert!(l.is_quantized(), "{name} must land as a packed struct");
        assert_eq!(lm.bytes_read(), expected, "touching {name} must read one section");
    }
    // Packed section decodes to the same kind the artifact reader gives.
    let direct = art.read_linear("b0.wq").unwrap();
    assert!(direct.is_quantized());
    std::fs::remove_file(path).ok();
}

#[test]
fn v1_checkpoint_loads_eagerly_through_the_registry() {
    // Old-format checkpoints (offsets only, no len/crc32) must keep
    // working: ArtifactFile refuses them, the registry falls back to the
    // eager loader, and served output matches the original model.
    let (mut m, path) = quantized_ckpt("v1compat", 7, AqlmShape::new(2, 5, 4));
    downgrade_to_v1(&path);
    assert!(
        ArtifactFile::open(&path).unwrap_err().to_string().contains("no section index"),
        "lazy open must reject a v1 checkpoint"
    );
    let expected = m.generate(&[5, 9, 2], 6, 0.0, &mut Rng::seed_from_u64(0));
    let registry = Arc::new(ModelRegistry::new(0));
    registry.register("old", &path);
    let got = registry.acquire("old").unwrap();
    let mut loaded = (*got).clone();
    let toks = loaded.generate(&[5, 9, 2], 6, 0.0, &mut Rng::seed_from_u64(0));
    assert_eq!(toks, expected, "v1 eager fallback drifted from the saved weights");
    std::fs::remove_file(path).ok();
}

/// Rewrite a v2 checkpoint header to the v1 format in place: downgrade
/// the format string and strip the `len`/`crc32` index fields, leaving
/// offsets only (exactly what pre-index checkpoints held).
fn downgrade_to_v1(path: &Path) {
    let bytes = std::fs::read(path).unwrap();
    let hlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let mut header = Json::parse(std::str::from_utf8(&bytes[16..16 + hlen]).unwrap()).unwrap();
    if let Json::Obj(map) = &mut header {
        map.insert("format".to_string(), Json::Str("aqlm-ckpt-v1".to_string()));
        if let Some(Json::Arr(tensors)) = map.get_mut("tensors") {
            for t in tensors {
                if let Json::Obj(meta) = t {
                    meta.remove("len");
                    meta.remove("crc32");
                }
            }
        }
    }
    let htext = format!("{header}");
    let mut out = Vec::new();
    out.extend_from_slice(&bytes[..8]);
    out.extend_from_slice(&(htext.len() as u64).to_le_bytes());
    out.extend_from_slice(htext.as_bytes());
    out.extend_from_slice(&bytes[16 + hlen..]);
    std::fs::write(path, out).unwrap();
}

#[test]
fn budgeted_multi_model_serving_is_token_identical_with_evictions() {
    // The PR's acceptance scenario: two quantized variants, a store
    // budget smaller than their summed resident bytes, one worker, an
    // interleaved request mix. Every response must be token-identical to
    // a single-model server run, and the store must report >= 1 eviction
    // (the worker rebinding between models forces the LRU out).
    let (_, pa) = quantized_ckpt("mix_a", 11, AqlmShape::new(2, 5, 4));
    let (_, pb) = quantized_ckpt("mix_b", 23, AqlmShape::new(1, 6, 4));
    let prompts: Vec<Vec<u32>> = vec![vec![5, 9, 2], vec![13, 1], vec![40, 3, 2], vec![7, 7]];
    let max_new = 6;

    // Single-model baselines through the same server machinery.
    let mut baseline: Vec<Vec<Vec<u32>>> = Vec::new();
    for path in [&pa, &pb] {
        let server = Server::start(Model::load(path).unwrap(), ServerConfig::default());
        let rxs: Vec<_> =
            prompts.iter().map(|p| server.submit(p.clone(), max_new, 0.0)).collect();
        baseline.push(
            rxs.into_iter()
                .map(|rx| rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap().tokens)
                .collect(),
        );
        server.shutdown();
    }

    // Budget: big enough for either model alone, smaller than both
    // together — every switch must evict the previous resident.
    let sa = std::fs::metadata(&pa).unwrap().len();
    let sb = std::fs::metadata(&pb).unwrap().len();
    let budget = sa.max(sb) + sa.min(sb) / 2;
    assert!(budget < sa + sb, "budget must not fit both models");
    let registry = Arc::new(ModelRegistry::new(budget));
    registry.register("a", &pa);
    registry.register("b", &pb);
    let cfg = ServerConfig { workers: 1, ..Default::default() };
    let server = Server::start_registry(Arc::clone(&registry), "a", cfg);

    // Interleave a/b sequentially (one at a time so the single worker
    // rebinds on every request — the maximally store-hostile schedule).
    for round in 0..2 {
        for (pi, prompt) in prompts.iter().enumerate() {
            for (mi, name) in ["a", "b"].iter().enumerate() {
                let opts =
                    SubmitOpts { model: Some(name.to_string()), ..Default::default() };
                let (_, rx) = server.submit_opts(prompt.clone(), max_new, 0.0, opts);
                let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
                assert_eq!(
                    resp.tokens, baseline[mi][pi],
                    "round {round}: model {name} prompt {pi} diverged from its \
                     single-model run"
                );
            }
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 2 * prompts.len() * 2);
    let store = stats.store.expect("registry servers report store stats");
    assert!(store.evictions >= 1, "budget pressure must evict at least once: {store:?}");
    assert!(
        store.bytes_resident <= budget,
        "idle store must fit the budget: {} resident vs {budget}",
        store.bytes_resident
    );
    let mut per: Vec<_> = store.per_model.clone();
    per.sort();
    let n = (2 * prompts.len()) as u64;
    assert_eq!(per, vec![("a".to_string(), n), ("b".to_string(), n)]);
    std::fs::remove_file(pa).ok();
    std::fs::remove_file(pb).ok();
}
