//! Property-based tests over the crate's core invariants, via the
//! built-in `propcheck` harness (proptest is unavailable offline).

use aqlm::kernels::format::{AqlmShape, AqlmWeight, PackedSpqr};
use aqlm::kernels::matvec::PackedAqlm;
use aqlm::kernels::packed::{pack, unpack};
use aqlm::quant::aqlm::beam::{beam_search_sweep, layer_loss};
use aqlm::quant::aqlm::codebook::{update_codebooks_adam, CodebookUpdateConfig};
use aqlm::quant::aqlm::kmeans::residual_kmeans_init;
use aqlm::quant::groupint::quantize_group_minmax;
use aqlm::tensor::ops::{matmul, matmul_at, matmul_bt};
use aqlm::tensor::Tensor;
use aqlm::util::propcheck::{check, check_no_shrink, shrink_vec, Config};
use aqlm::util::rng::Rng;

fn cfg(cases: usize) -> Config {
    Config { cases, ..Default::default() }
}

// --------------------------------------------------------------- bit packing

#[test]
fn prop_pack_unpack_roundtrip_all_widths() {
    check(
        "pack-unpack",
        &cfg(96),
        |rng: &mut Rng| {
            let bits = 1 + rng.below(16);
            let n = 1 + rng.below(300);
            let vals: Vec<u16> = (0..n).map(|_| rng.below(1usize << bits) as u16).collect();
            (bits, vals)
        },
        |(bits, vals)| {
            let mut shrunk: Vec<(usize, Vec<u16>)> = Vec::new();
            for v in shrink_vec(vals) {
                shrunk.push((*bits, v));
            }
            shrunk
        },
        |(bits, vals)| {
            let packed = pack(vals, *bits);
            let got = unpack(&packed, *bits, vals.len());
            if got == *vals {
                Ok(())
            } else {
                Err(format!("roundtrip failed at bits={bits}"))
            }
        },
    );
}

// ------------------------------------------------------------ scalar quant

#[test]
fn prop_groupint_error_bounded_by_half_scale() {
    check_no_shrink(
        "rtn-error-bound",
        &cfg(128),
        |rng: &mut Rng| {
            let bits = 2 + rng.below(7);
            let n = 2 + rng.below(32);
            let mut vals = vec![0.0f32; n];
            let std = 1.0 + rng.f32() * 5.0;
            rng.fill_normal(&mut vals, std);
            (bits, vals)
        },
        |(bits, vals)| {
            let (codes, s, z) = quantize_group_minmax(vals, *bits);
            for (&c, &v) in codes.iter().zip(vals) {
                let deq = s * (c as f32 - z);
                if (deq - v).abs() > s * 0.5 + 1e-5 {
                    return Err(format!("|{deq} - {v}| > scale/2 = {}", s * 0.5));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- AQLM core

fn random_aqlm(rng: &mut Rng) -> (AqlmWeight, Tensor, Tensor) {
    let g = [2usize, 4][rng.below(2)];
    let n_groups = 1 + rng.below(4);
    let d_in = g * n_groups;
    let d_out = 2 + rng.below(10);
    let bits = 2 + rng.below(3);
    let m = 1 + rng.below(2);
    let w = Tensor::randn(&[d_out, d_in], 0.7, rng);
    let q = residual_kmeans_init(&w, AqlmShape::new(m, bits, g), 6, rng);
    // Random SPD calibration.
    let a = Tensor::randn(&[d_in + 2, d_in], 1.0, rng);
    let xxt = matmul_at(&a, &a);
    (q, w, xxt)
}

#[test]
fn prop_beam_search_never_increases_loss() {
    check_no_shrink(
        "beam-monotone",
        &cfg(24),
        |rng: &mut Rng| {
            let (q, w, xxt) = random_aqlm(rng);
            let beam = 1 + rng.below(3);
            (q, w, xxt, beam)
        },
        |(q, w, xxt, beam)| {
            let mut q = q.clone();
            let before = layer_loss(&q, w, xxt);
            let after = beam_search_sweep(&mut q, w, xxt, *beam);
            if after <= before * (1.0 + 1e-5) + 1e-9 {
                Ok(())
            } else {
                Err(format!("loss rose {before} -> {after} (beam {beam})"))
            }
        },
    );
}

#[test]
fn prop_codebook_update_never_increases_loss() {
    check_no_shrink(
        "codebook-adam-monotone",
        &cfg(16),
        |rng: &mut Rng| random_aqlm(rng),
        |(q, w, xxt)| {
            let mut q = q.clone();
            let (initial, final_loss) = update_codebooks_adam(
                &mut q,
                w,
                xxt,
                CodebookUpdateConfig { steps: 30, lr: 5e-4, tol: 0.0 },
            );
            // Absolute slack: when K-means already fits exactly (loss ~ 0),
            // finite Adam steps wander at float-noise level (~1e-5) without
            // that being a real regression.
            if final_loss <= initial * 1.02 + 1e-4 {
                Ok(())
            } else {
                Err(format!("adam increased loss {initial} -> {final_loss}"))
            }
        },
    );
}

#[test]
fn prop_decode_linearity_in_scales() {
    // decode(2·s) == 2·decode(s): the format is linear in the scales.
    check_no_shrink(
        "decode-scale-linearity",
        &cfg(32),
        |rng: &mut Rng| random_aqlm(rng),
        |(q, _, _)| {
            let base = q.decode();
            let mut q2 = q.clone();
            for s in &mut q2.scales {
                *s *= 2.0;
            }
            let doubled = q2.decode();
            let mut expect = base.clone();
            expect.scale_assign(2.0);
            if doubled.allclose(&expect, 1e-5) {
                Ok(())
            } else {
                Err("decode not linear in scales".into())
            }
        },
    );
}

#[test]
fn prop_packed_kernels_agree_with_dense() {
    check_no_shrink(
        "kernels-vs-dense",
        &cfg(24),
        |rng: &mut Rng| {
            let (q, _, _) = random_aqlm(rng);
            let x: Vec<f32> = (0..q.d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (q, x)
        },
        |(q, x)| {
            let dense = q.decode();
            let mut y_ref = vec![0.0f32; q.d_out];
            aqlm::tensor::ops::gemv(&dense, x, &mut y_ref);
            let packed = PackedAqlm::from_weight(q);
            let mut y_dec = vec![0.0f32; q.d_out];
            packed.matvec_decode(x, &mut y_dec);
            let mut lut = vec![0.0f32; packed.lut_len()];
            let mut y_lut = vec![0.0f32; q.d_out];
            packed.matvec_lut(x, &mut lut, &mut y_lut);
            for i in 0..q.d_out {
                let tol = 1e-3 * (1.0 + y_ref[i].abs());
                if (y_dec[i] - y_ref[i]).abs() > tol {
                    return Err(format!("decode kernel row {i}: {} vs {}", y_dec[i], y_ref[i]));
                }
                if (y_lut[i] - y_ref[i]).abs() > tol {
                    return Err(format!("lut kernel row {i}: {} vs {}", y_lut[i], y_ref[i]));
                }
            }
            Ok(())
        },
    );
}

/// Random deployed-format weight spanning both phase-2 code paths
/// (byte-aligned for B ≤ 8, BitReader above) and group sizes up to 16.
fn random_packed_weight(rng: &mut Rng) -> AqlmWeight {
    let g = [4usize, 8, 16][rng.below(3)];
    let n_groups = 1 + rng.below(4);
    let d_in = g * n_groups;
    let d_out = 1 + rng.below(24);
    let m = 1 + rng.below(3);
    let bits = 3 + rng.below(8); // 3..=10, includes odd widths like 5
    let k = 1usize << bits;
    AqlmWeight {
        d_out,
        d_in,
        group: g,
        n_codebooks: m,
        code_bits: bits,
        codes: (0..d_out * n_groups * m).map(|_| rng.below(k) as u16).collect(),
        codebooks: (0..m).map(|_| Tensor::randn(&[k, g], 0.4, rng)).collect(),
        scales: (0..d_out).map(|_| 0.5 + rng.f32()).collect(),
    }
}

#[test]
fn prop_batched_kernels_bitexact_vs_sequential() {
    // The server's greedy-parity guarantee: one matmat call must equal n
    // independent matvec calls bit-for-bit, for every kernel and shape.
    check_no_shrink(
        "matmat-vs-matvec",
        &cfg(32),
        |rng: &mut Rng| {
            let q = random_packed_weight(rng);
            let n = 1 + rng.below(8);
            let xs: Vec<f32> = (0..n * q.d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (q, n, xs)
        },
        |(q, n, xs)| {
            let packed = PackedAqlm::from_weight(q);
            let (n, d_in, d_out) = (*n, q.d_in, q.d_out);
            let mut y1 = vec![0.0f32; n * d_out];
            let mut lut = vec![0.0f32; packed.lut_len()];
            for b in 0..n {
                packed.matvec_lut(&xs[b * d_in..(b + 1) * d_in], &mut lut, &mut y1[b * d_out..(b + 1) * d_out]);
            }
            let mut y2 = vec![0.0f32; n * d_out];
            let mut blut = vec![0.0f32; n * packed.lut_len()];
            packed.matmat_lut(xs, n, &mut blut, &mut y2);
            if y1.iter().zip(&y2).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("matmat_lut != n×matvec_lut (bitwise), B={}", q.code_bits));
            }
            for b in 0..n {
                packed.matvec_decode(&xs[b * d_in..(b + 1) * d_in], &mut y1[b * d_out..(b + 1) * d_out]);
            }
            packed.matmat_decode(xs, n, &mut y2);
            if y1.iter().zip(&y2).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!("matmat_decode != n×matvec_decode (bitwise), g={}", q.group));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------- packed SpQR

/// Random packed-SpQR weight: random shape (ragged tails included), bit
/// width, group size and outlier fraction. Construction goes through
/// `PackedSpqr::from_parts` — the same CSR builder the quantizer uses —
/// so the property tests exercise exactly the production layout.
fn random_packed_spqr(rng: &mut Rng) -> PackedSpqr {
    let d_out = 1 + rng.below(24);
    let d_in = 1 + rng.below(48);
    let group = 1 + rng.below(20); // often does not divide d_in
    let bits = 2 + rng.below(7); // 2..=8
    let frac = rng.f64() * 0.1;
    let n_groups = d_in.div_ceil(group);
    let codes: Vec<u16> = (0..d_out * d_in).map(|_| rng.below(1usize << bits) as u16).collect();
    let scales: Vec<f32> = (0..d_out * n_groups).map(|_| 0.05 + rng.f32()).collect();
    let zeros: Vec<f32> =
        (0..d_out * n_groups).map(|_| rng.f32() * ((1usize << bits) - 1) as f32).collect();
    let n_out = ((d_out * d_in) as f64 * frac).round() as usize;
    let mut flats: Vec<usize> = Vec::new();
    while flats.len() < n_out {
        let f = rng.below(d_out * d_in);
        if !flats.contains(&f) {
            flats.push(f);
        }
    }
    flats.sort_unstable();
    let outliers: Vec<(usize, f32)> =
        flats.iter().map(|&f| (f, rng.normal_f32(0.0, 5.0))).collect();
    PackedSpqr::from_parts(d_out, d_in, group, bits, &codes, scales, zeros, &outliers).unwrap()
}

#[test]
fn prop_packed_spqr_matvec_bitexact_vs_dense() {
    // The packed sparse-outlier kernel must equal a dense GEMV over the
    // decoded matrix within **0 ulp**, and the batched kernel must equal
    // repeated single-vector calls bit-for-bit — for random shapes
    // (ragged tails included) and outlier fractions.
    check_no_shrink(
        "spqr-matvec-vs-dense",
        &cfg(48),
        |rng: &mut Rng| {
            let q = random_packed_spqr(rng); // from_parts validates on build
            let n = 1 + rng.below(8);
            let xs: Vec<f32> = (0..n * q.d_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (q, n, xs)
        },
        |(q, n, xs)| {
            let (n, d_in, d_out) = (*n, q.d_in, q.d_out);
            let dense = q.decode();
            let mut scratch = Vec::new();
            let mut y = vec![0.0f32; d_out];
            let mut y_ref = vec![0.0f32; d_out];
            let mut y_single = vec![0.0f32; n * d_out];
            for b in 0..n {
                let x = &xs[b * d_in..(b + 1) * d_in];
                q.matvec(x, &mut scratch, &mut y);
                aqlm::tensor::ops::gemv(&dense, x, &mut y_ref);
                for i in 0..d_out {
                    if y[i].to_bits() != y_ref[i].to_bits() {
                        return Err(format!(
                            "row {i} not bit-equal to dense (g={}, d_in={}, bits={}): {} vs {}",
                            q.group, d_in, q.bits, y[i], y_ref[i]
                        ));
                    }
                }
                y_single[b * d_out..(b + 1) * d_out].copy_from_slice(&y);
            }
            let mut ys = vec![0.0f32; n * d_out];
            q.matvec_batch(xs, n, &mut scratch, &mut ys);
            if ys.iter().zip(&y_single).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err(format!(
                    "matvec_batch != n×matvec (bitwise), n={n} g={} d_in={d_in}",
                    q.group
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_spqr_ragged_accounting() {
    // Ragged tails: every column is covered by a scale group, and the bits
    // accounting matches a hand count of the packed arrays.
    check_no_shrink(
        "spqr-ragged-accounting",
        &cfg(64),
        |rng: &mut Rng| random_packed_spqr(rng),
        |q| {
            let ng = q.n_groups();
            if ng != q.d_in.div_ceil(q.group) {
                return Err("n_groups truncated".into());
            }
            let covered: usize = (0..ng).map(|j| q.group_width(j)).sum();
            if covered != q.d_in {
                return Err(format!("groups cover {covered} of {} columns", q.d_in));
            }
            let hand = q.d_out * q.d_in * q.bits
                + q.d_out * ng * 2 * 16
                + q.values.len() * (16 + 32)
                + (q.d_out + 1) * 32;
            if q.size_bits() != hand {
                return Err(format!("size_bits {} != hand count {hand}", q.size_bits()));
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------------- tensor alg

#[test]
fn prop_matmul_transpose_identities() {
    check_no_shrink(
        "matmul-identities",
        &cfg(32),
        |rng: &mut Rng| {
            let m = 1 + rng.below(8);
            let k = 1 + rng.below(8);
            let n = 1 + rng.below(8);
            (Tensor::randn(&[m, k], 1.0, rng), Tensor::randn(&[n, k], 1.0, rng))
        },
        |(a, b)| {
            // A·Bᵀ == (B·Aᵀ)ᵀ and matmul_bt == matmul(a, bᵀ).
            let left = matmul_bt(a, b);
            let right = matmul_bt(b, a).transpose();
            let direct = matmul(a, &b.transpose());
            if !left.allclose(&right, 1e-4) {
                return Err("ABᵀ != (BAᵀ)ᵀ".into());
            }
            if !left.allclose(&direct, 1e-4) {
                return Err("matmul_bt != matmul(a, bᵀ)".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_appendix_h_formula_matches_struct_accounting() {
    check_no_shrink(
        "appendix-h",
        &cfg(48),
        |rng: &mut Rng| {
            let g = [2usize, 4, 8][rng.below(3)];
            let n_groups = 1 + rng.below(6);
            let d_in = g * n_groups;
            let d_out = 1 + rng.below(24);
            let shape = AqlmShape::new(1 + rng.below(3), 2 + rng.below(5), g);
            (d_out, d_in, shape)
        },
        |(d_out, d_in, shape)| {
            let mut rng2 = Rng::seed_from_u64(9);
            let w = Tensor::randn(&[*d_out, *d_in], 0.5, &mut rng2);
            let q = residual_kmeans_init(&w, *shape, 2, &mut rng2);
            let formula = shape.avg_bits_for(*d_out, *d_in);
            if (q.avg_bits() - formula).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("struct {} vs formula {}", q.avg_bits(), formula))
            }
        },
    );
}

// ------------------------------------------------------------- method specs

#[test]
fn prop_method_spec_display_parse_roundtrip() {
    use aqlm::quant::aqlm::blockft::FtScope;
    use aqlm::quant::spec::{AqlmSpec, MethodSpec, ShapeChoice};
    let gen_spec = |rng: &mut Rng| -> MethodSpec {
        match rng.below(5) {
            0 => MethodSpec::Aqlm(AqlmSpec {
                shape: if rng.below(2) == 0 {
                    ShapeChoice::Fixed(AqlmShape::new(
                        1 + rng.below(4),
                        1 + rng.below(10),
                        [4usize, 8, 16, 32][rng.below(4)],
                    ))
                } else {
                    // Multiples of 1/8 are exact in f64, so Display is exact.
                    ShapeChoice::Auto { target_bits: (1 + rng.below(60)) as f64 / 8.0 }
                },
                ft_steps: rng.below(100),
                scope: [
                    FtScope::None,
                    FtScope::NormsOnly,
                    FtScope::QuantParamsOnly,
                    FtScope::Full,
                ][rng.below(4)],
                fast: rng.below(2) == 0,
            }),
            1 => MethodSpec::Rtn {
                bits: 1 + rng.below(8),
                group: [8usize, 16, 32, 64][rng.below(4)],
            },
            2 => MethodSpec::Gptq {
                bits: 1 + rng.below(8),
                group: if rng.below(2) == 0 { None } else { Some([8usize, 16, 32][rng.below(3)]) },
                tune_steps: if rng.below(2) == 0 { None } else { Some(1 + rng.below(120)) },
            },
            3 => MethodSpec::Spqr {
                bits: 1 + rng.below(8),
                group: [8usize, 16, 32][rng.below(3)],
                // Exact decimal fractions: f64 Display round-trips bit-for-bit.
                outlier_frac: (1 + rng.below(50)) as f64 / 1000.0,
            },
            _ => MethodSpec::Quip { bits: 1 + rng.below(8), seed: rng.next_u64() },
        }
    };
    check_no_shrink(
        "method-spec-roundtrip",
        &cfg(256),
        gen_spec,
        |spec| {
            let s = format!("{spec}");
            match MethodSpec::parse(&s) {
                Ok(back) if back == *spec => Ok(()),
                Ok(back) => Err(format!("'{s}' reparsed as {back:?}")),
                Err(e) => Err(format!("'{s}' failed to parse: {e}")),
            }
        },
    );
}

#[test]
fn prop_allocator_output_closed_under_policy_grammar_and_monotone() {
    // Any allocator-emitted policy string parses back to an identical
    // assignment (Display ↔ parse closed under `alloc` output), and a
    // larger budget never narrows a layer.
    use aqlm::quant::alloc::{allocate, emit_policy, Candidate, LayerOption, LayerSensitivity};
    use aqlm::quant::spec::{LayerPolicy, MethodSpec};
    let spec_pool: Vec<MethodSpec> = [
        "aqlm:1x6,g=4,ft=0,fast",
        "aqlm:2x8,g=8,ft=30",
        "aqlm:1x8,g=8,ft=15,scope=norms",
        "rtn:b=2,g=32",
        "gptq:b=3,g=16,tuned",
    ]
    .iter()
    .map(|s| MethodSpec::parse(s).unwrap())
    .collect();
    check_no_shrink(
        "alloc-emit-roundtrip",
        &cfg(64),
        |rng: &mut Rng| {
            let n_cand = 2 + rng.below(4);
            let candidates: Vec<Candidate> = (0..n_cand)
                .map(|_| {
                    let s = spec_pool[rng.below(spec_pool.len())];
                    Candidate { probe: s, emit: s }
                })
                .collect();
            let n_layers = 1 + rng.below(20);
            let table: Vec<LayerSensitivity> = (0..n_layers)
                .map(|j| LayerSensitivity {
                    layer: format!("b{}.w{}", j / 7, j % 7),
                    params: 64 + rng.below(4096),
                    options: (0..n_cand)
                        .map(|_| LayerOption {
                            avg_bits: (8 + rng.below(96)) as f64 / 8.0,
                            rel_error: rng.f64() * 0.5,
                        })
                        .collect(),
                })
                .collect();
            // Target at or above the narrowest mixture, so always feasible.
            let (mut min_bits, mut params) = (0.0f64, 0usize);
            for row in &table {
                let narrowest =
                    row.options.iter().map(|o| o.avg_bits).fold(f64::INFINITY, f64::min);
                min_bits += narrowest * row.params as f64;
                params += row.params;
            }
            let target = min_bits / params as f64 + rng.f64() * 3.0;
            (candidates, table, target)
        },
        |(candidates, table, target)| {
            let a = allocate(table, *target).map_err(|e| e.to_string())?;
            if a.avg_bits > target + 1e-9 {
                return Err(format!("overshot budget: {} > {target}", a.avg_bits));
            }
            let policy = emit_policy(table, candidates, &a);
            let s = policy.to_string();
            let back = LayerPolicy::parse(&s).map_err(|e| format!("'{s}' failed to parse: {e}"))?;
            if back != policy {
                return Err(format!("'{s}' reparsed to a different assignment"));
            }
            for (row, &c) in table.iter().zip(&a.choice) {
                if back.spec_for(&row.layer) != Some(&candidates[c].emit) {
                    return Err(format!("reparsed policy routes {} differently", row.layer));
                }
            }
            let a2 = allocate(table, target + 1.0).map_err(|e| e.to_string())?;
            for (j, row) in table.iter().enumerate() {
                if row.bits(a2.choice[j]) < row.bits(a.choice[j]) - 1e-12 {
                    return Err(format!("layer {} narrowed when the budget grew", row.layer));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_grouped_allocation_coalesces_exactly_and_never_overshoots() {
    // At every granularity: the solved assignment never overshoots the
    // budget, a larger budget never narrows a unit, and the *coalesced*
    // policy (block/expert globs, `LayerPolicy::coalesce`) re-parses to
    // the exact per-layer assignment it was emitted from.
    use aqlm::quant::alloc::{
        allocate_at, emit_policy, Candidate, Granularity, LayerOption, LayerSensitivity,
    };
    use aqlm::quant::spec::{LayerPolicy, MethodSpec};
    let spec_pool: Vec<MethodSpec> = [
        "aqlm:1x6,g=4,ft=0,fast",
        "aqlm:2x8,g=8,ft=30",
        "rtn:b=2,g=32",
        "gptq:b=3,g=16",
        "spqr:b=3,g=16,out=0.01",
    ]
    .iter()
    .map(|s| MethodSpec::parse(s).unwrap())
    .collect();
    check_no_shrink(
        "grouped-alloc-coalesce",
        &cfg(64),
        |rng: &mut Rng| {
            let n_cand = 2 + rng.below(4);
            let candidates: Vec<Candidate> = (0..n_cand)
                .map(|_| {
                    let s = spec_pool[rng.below(spec_pool.len())];
                    Candidate { probe: s, emit: s }
                })
                .collect();
            // Block-structured names, with MoE expert layers on some
            // blocks so PerExpert grouping has real work to do.
            let n_blocks = 1 + rng.below(6);
            let mut table: Vec<LayerSensitivity> = Vec::new();
            for b in 0..n_blocks {
                let mut names: Vec<String> =
                    (0..4).map(|j| format!("b{b}.w{j}")).collect();
                if rng.below(2) == 0 {
                    for e in 0..1 + rng.below(3) {
                        for leaf in ["wg", "wd"] {
                            names.push(format!("b{b}.e{e}.{leaf}"));
                        }
                    }
                }
                for name in names {
                    table.push(LayerSensitivity {
                        layer: name,
                        params: 64 + rng.below(4096),
                        options: (0..n_cand)
                            .map(|_| LayerOption {
                                avg_bits: (8 + rng.below(96)) as f64 / 8.0,
                                rel_error: rng.f64() * 0.5,
                            })
                            .collect(),
                    });
                }
            }
            // Target at or above the narrowest mixture, so always feasible.
            let (mut min_bits, mut params) = (0.0f64, 0usize);
            for row in &table {
                let narrowest =
                    row.options.iter().map(|o| o.avg_bits).fold(f64::INFINITY, f64::min);
                min_bits += narrowest * row.params as f64;
                params += row.params;
            }
            // Grouped rows average their members' bits, so the grouped
            // minimum can sit above the per-layer minimum: leave headroom.
            let target = min_bits / params as f64 + 2.0 + rng.f64() * 3.0;
            let gran = [Granularity::PerLayer, Granularity::PerBlock, Granularity::PerExpert]
                [rng.below(3)];
            (candidates, table, target, gran)
        },
        |(candidates, table, target, gran)| {
            let a = match allocate_at(table, *target, *gran) {
                Ok(a) => a,
                // A coarse grouping can make a near-minimum target
                // infeasible (bits average across members); that is the
                // documented contract, not a failure.
                Err(e) if e.to_string().contains("infeasible") => return Ok(()),
                Err(e) => return Err(e.to_string()),
            };
            if a.avg_bits > target + 1e-9 {
                return Err(format!("overshot budget at {gran}: {} > {target}", a.avg_bits));
            }
            // Group-uniformity: members of one unit share one choice.
            for (i, row) in table.iter().enumerate() {
                for (j, other) in table.iter().enumerate() {
                    if gran.key_of(&row.layer) == gran.key_of(&other.layer)
                        && a.choice[i] != a.choice[j]
                    {
                        return Err(format!(
                            "{} and {} share a {gran} group but chose differently",
                            row.layer, other.layer
                        ));
                    }
                }
            }
            let policy = emit_policy(table, candidates, &a);
            let s = policy.to_string();
            let back =
                LayerPolicy::parse(&s).map_err(|e| format!("'{s}' failed to parse: {e}"))?;
            if back != policy {
                return Err(format!("'{s}' reparsed to a different assignment"));
            }
            for (row, &c) in table.iter().zip(&a.choice) {
                if back.spec_for(&row.layer) != Some(&candidates[c].emit) {
                    return Err(format!(
                        "coalesced policy routes {} differently at {gran}",
                        row.layer
                    ));
                }
            }
            let a2 = allocate_at(table, target + 1.0, *gran).map_err(|e| e.to_string())?;
            for (j, row) in table.iter().enumerate() {
                if row.bits(a2.choice[j]) < row.bits(a.choice[j]) - 1e-12 {
                    return Err(format!(
                        "layer {} narrowed when the budget grew at {gran}",
                        row.layer
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layer_policy_display_parse_roundtrip() {
    use aqlm::quant::spec::{LayerPolicy, MethodSpec};
    let specs: Vec<MethodSpec> = [
        "aqlm:2x8,g=8,ft=30",
        "aqlm:bits=2.5,ft=0,fast",
        "rtn:b=4,g=32",
        "gptq:b=2,g=16,tuned",
        "spqr:b=3,g=16,out=0.01",
        "quip:b=2,seed=7",
    ]
    .iter()
    .map(|s| MethodSpec::parse(s).unwrap())
    .collect();
    let patterns = ["*", "*.wq", "*.wk", "*.wd", "b0.*", "b1.e*.wg"];
    check_no_shrink(
        "layer-policy-roundtrip",
        &cfg(128),
        |rng: &mut Rng| {
            let n = 1 + rng.below(4);
            let rules: Vec<(String, MethodSpec)> = (0..n)
                .map(|_| {
                    (patterns[rng.below(patterns.len())].to_string(), specs[rng.below(specs.len())])
                })
                .collect();
            LayerPolicy { rules }
        },
        |policy| {
            let s = format!("{policy}");
            match LayerPolicy::parse(&s) {
                Ok(back) if back == *policy => Ok(()),
                Ok(back) => Err(format!("'{s}' reparsed as {back:?}")),
                Err(e) => Err(format!("'{s}' failed to parse: {e}")),
            }
        },
    );
}

// ------------------------------------------------------------- paged KV cache

#[test]
fn prop_paged_decode_bit_identical_to_contiguous() {
    // Random model shapes, random ragged per-lane histories (lengths that
    // straddle block boundaries, block sizes down to 1), every KV storage
    // width: batched decode through the paged pool must produce
    // bit-identical logits to the contiguous per-sequence caches at every
    // step. Quantized widths are lossy relative to f32, but paged and
    // contiguous share one row codec, so they must still agree exactly
    // with *each other*.
    use aqlm::nn::config::ModelConfig;
    use aqlm::nn::kvcache::{KvBits, LayerKvCache, PagedSeqKv};
    use aqlm::nn::model::Model;
    check_no_shrink(
        "paged-vs-contig",
        &cfg(16),
        |rng: &mut Rng| {
            let n_layers = 1 + rng.below(2);
            let n_kv_heads = [1usize, 2][rng.below(2)];
            let block_size = 1 + rng.below(4);
            let n_lanes = 1 + rng.below(3);
            let lens: Vec<usize> = (0..n_lanes).map(|_| 1 + rng.below(10)).collect();
            let kv_bits = KvBits::ALL[rng.below(KvBits::ALL.len())];
            let seed = rng.next_u64();
            (n_layers, n_kv_heads, block_size, lens, kv_bits, seed)
        },
        |(n_layers, n_kv_heads, block_size, lens, kv_bits, seed)| {
            let mut mc = ModelConfig::nano();
            mc.d_model = 8;
            mc.n_heads = 2;
            mc.n_kv_heads = *n_kv_heads;
            mc.d_ff = 12;
            mc.vocab_size = 24;
            mc.max_seq = 16;
            mc.n_layers = *n_layers;
            let mut rng = Rng::seed_from_u64(*seed);
            let mut model = Model::init(&mc, &mut rng);
            model.warm_decode();
            let n = lens.len();
            let max_len = *lens.iter().max().unwrap();
            let tokens: Vec<Vec<u32>> = lens
                .iter()
                .map(|&l| (0..l).map(|_| rng.below(24) as u32).collect())
                .collect();
            let mut contig: Vec<Vec<LayerKvCache>> =
                (0..n).map(|_| model.new_kv_caches_with(*kv_bits)).collect();
            let n_blocks = n * mc.n_layers * max_len.div_ceil(*block_size);
            let mut pool = model.new_kv_pool_with(*block_size, n_blocks, *kv_bits);
            let mut paged: Vec<PagedSeqKv> = (0..n).map(|_| model.new_paged_kv()).collect();
            let mut scratch_a = Vec::new();
            let mut scratch_b = Vec::new();
            for t in 0..max_len {
                let lanes: Vec<usize> = (0..n).filter(|&b| t < lens[b]).collect();
                let toks: Vec<u32> = lanes.iter().map(|&b| tokens[b][t]).collect();
                let poss: Vec<usize> = lanes.iter().map(|_| t).collect();
                let mut kv_refs: Vec<&mut Vec<LayerKvCache>> = Vec::new();
                let mut li = 0;
                for (b, kv) in contig.iter_mut().enumerate() {
                    if li < lanes.len() && lanes[li] == b {
                        kv_refs.push(kv);
                        li += 1;
                    }
                }
                let mut pg_refs: Vec<&mut PagedSeqKv> = Vec::new();
                let mut pi = 0;
                for (b, pg) in paged.iter_mut().enumerate() {
                    if pi < lanes.len() && lanes[pi] == b {
                        pg_refs.push(pg);
                        pi += 1;
                    }
                }
                let la = model.decode_batch(&toks, &poss, &mut kv_refs, &mut scratch_a);
                let lb =
                    model.decode_batch_paged(&toks, &poss, &mut pool, &mut pg_refs, &mut scratch_b);
                for (lane, (x, y)) in la.iter().zip(&lb).enumerate() {
                    for (a, b) in x.iter().zip(y) {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "paged logits diverged at step {t} lane {lane} \
                                 (bs={block_size}, layers={n_layers}, lens={lens:?}, \
                                 kv_bits={kv_bits})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_codec_roundtrip_error_bounded() {
    // The packed KV group-int codec over random shapes — ragged head_dim
    // (head_dim % KV_GROUP != 0, code rows not word-aligned), block_size
    // down to 1, every quantized width: each dequantized value must sit
    // within the RTN group bound scale/2 of its source, where scale is
    // recomputed here from the group's min/max exactly as the quantizer
    // derives it ((hi − lo) / (2^b − 1)).
    use aqlm::nn::kvcache::{BlockTable, KvBits, KvPool, KV_GROUP};
    check_no_shrink(
        "kv-codec-bound",
        &cfg(64),
        |rng: &mut Rng| {
            let kv_bits = [KvBits::B8, KvBits::B4, KvBits::B3][rng.below(3)];
            let heads = 1 + rng.below(3);
            let head_dim = 1 + rng.below(96);
            let block_size = 1 + rng.below(4);
            let positions = 1 + rng.below(9);
            let seed = rng.next_u64();
            (kv_bits, heads, head_dim, block_size, positions, seed)
        },
        |(kv_bits, heads, head_dim, block_size, positions, seed)| {
            let (heads, hd, bs, n_pos) = (*heads, *head_dim, *block_size, *positions);
            let bits = kv_bits.bits().expect("quantized width");
            let qmax = ((1usize << bits) - 1) as f32;
            let mut rng = Rng::seed_from_u64(*seed);
            let n_blocks = n_pos.div_ceil(bs).max(1);
            let mut pool = KvPool::new_with(heads, hd, bs, n_blocks, *kv_bits);
            let mut table = BlockTable::new();
            let mut rows: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n_pos {
                let k: Vec<f32> = (0..heads * hd).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                pool.append(&mut table, &k, &k);
                rows.push(k);
            }
            pool.validate().map_err(|e| format!("pool failed validate(): {e}"))?;
            let mut scratch = vec![0.0f32; hd];
            for (t, krow) in rows.iter().enumerate() {
                for h in 0..heads {
                    let src = &krow[h * hd..(h + 1) * hd];
                    let deq = pool.k_row(&table, h, t, &mut scratch);
                    for g in 0..hd.div_ceil(KV_GROUP) {
                        let lo = g * KV_GROUP;
                        let hi = (lo + KV_GROUP).min(hd);
                        let (gmin, gmax) = src[lo..hi].iter().fold(
                            (f32::INFINITY, f32::NEG_INFINITY),
                            |(a, b), &x| (a.min(x), b.max(x)),
                        );
                        let bound = (gmax - gmin) / qmax * 0.5 + 1e-5;
                        for i in lo..hi {
                            if (deq[i] - src[i]).abs() > bound {
                                return Err(format!(
                                    "kv_bits={kv_bits} hd={hd} bs={bs}: |{} - {}| > {bound} \
                                     at h={h} t={t} i={i}",
                                    deq[i], src[i]
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kv_append_order_equivalence() {
    // Quantize-on-append must equal quantize-all-at-once: every row is
    // encoded independently from its own values, so a cache filled one
    // position at a time reads back bit-identical to an independent
    // per-row reference built directly from quantize_group_minmax over the
    // same values — append order cannot change any stored bit.
    use aqlm::nn::kvcache::{KvBits, LayerKvCache, KV_GROUP};
    check_no_shrink(
        "kv-append-order",
        &cfg(64),
        |rng: &mut Rng| {
            let kv_bits = [KvBits::B8, KvBits::B4, KvBits::B3][rng.below(3)];
            let heads = 1 + rng.below(3);
            let head_dim = 1 + rng.below(96);
            let positions = 1 + rng.below(8);
            let seed = rng.next_u64();
            (kv_bits, heads, head_dim, positions, seed)
        },
        |(kv_bits, heads, head_dim, positions, seed)| {
            let (heads, hd, n_pos) = (*heads, *head_dim, *positions);
            let bits = kv_bits.bits().expect("quantized width");
            let mut rng = Rng::seed_from_u64(*seed);
            let mut cache = LayerKvCache::new_with(heads, hd, n_pos, *kv_bits);
            let mut rows: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n_pos {
                let k: Vec<f32> = (0..heads * hd).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                cache.append(&k, &k);
                rows.push(k);
            }
            let mut scratch = vec![0.0f32; hd];
            for (t, krow) in rows.iter().enumerate() {
                for h in 0..heads {
                    let src = &krow[h * hd..(h + 1) * hd];
                    // Reference: quantize the whole row at once, group by
                    // group, straight through the scalar quantizer.
                    let mut want = vec![0.0f32; hd];
                    for g in 0..hd.div_ceil(KV_GROUP) {
                        let lo = g * KV_GROUP;
                        let hi = (lo + KV_GROUP).min(hd);
                        let (codes, s, z) = quantize_group_minmax(&src[lo..hi], bits);
                        for (i, &c) in codes.iter().enumerate() {
                            want[lo + i] = s * (c as f32 - z);
                        }
                    }
                    let got = cache.k_row(h, t, &mut scratch);
                    for i in 0..hd {
                        if got[i].to_bits() != want[i].to_bits() {
                            return Err(format!(
                                "kv_bits={kv_bits} hd={hd}: streamed append diverged from \
                                 all-at-once reference at h={h} t={t} i={i} ({} vs {})",
                                got[i], want[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
