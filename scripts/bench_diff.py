#!/usr/bin/env python3
"""Diff two benchmark JSON files produced by the cargo bench harnesses
(stdlib only — CI has no extra Python packages).

Usage:
    python3 scripts/bench_diff.py PREVIOUS.json CURRENT.json

Supports both payload kinds, dispatching on the top-level "bench" field:

  * "generation_speed" (BENCH_generation.json, `--bench generation_speed`):
    runs keyed by (max_batch, workers, kernel_threads, kv_bits); tok/s and
    queue/compute p50/p95/p99 deltas. kv_bits defaults to 32 (f32 KV
    cache) so payloads from before the axis existed keep diffing against
    the lossless runs.
  * "kernel_speed" (BENCH_kernels.json, `--bench kernel_speed`): runs
    keyed by (kernel, method, d_out, d_in, n); ns/op and bytes-read
    deltas.

For each key present in both files the script prints per-metric deltas;
keys only in one file are listed as added/removed. Exit code is always
0 — the diff is informational trend tracking, not a gate (wall-clock
numbers on shared CI runners are too noisy to fail a build on).
"""

import json
import sys

# Per-bench-kind schema: how runs are keyed, how a key renders, and which
# metrics to diff (field, label, display scale).
SCHEMAS = {
    "generation_speed": {
        # kernel_threads defaults to 1 and kv_bits to 32 so payloads from
        # before either axis existed keep keying (and diffing) against the
        # serial / f32-KV runs.
        "key": lambda r: (
            int(r.get("max_batch", 0)),
            int(r.get("workers", 0)),
            int(r.get("kernel_threads", 1)),
            int(r.get("kv_bits", 32)),
        ),
        "tag": lambda k: f"max_batch={k[0]} workers={k[1]} kthreads={k[2]} kv={k[3]}",
        "metrics": [
            ("tok_s", "tok/s", 1.0),
            ("queue_p50_s", "queue p50 (ms)", 1e3),
            ("queue_p95_s", "queue p95 (ms)", 1e3),
            ("queue_p99_s", "queue p99 (ms)", 1e3),
            ("compute_p50_s", "compute p50 (ms)", 1e3),
            ("compute_p95_s", "compute p95 (ms)", 1e3),
            ("compute_p99_s", "compute p99 (ms)", 1e3),
        ],
    },
    "kernel_speed": {
        "key": lambda r: (
            str(r.get("kernel", "")),
            str(r.get("method", "")),
            int(r.get("d_out", 0)),
            int(r.get("d_in", 0)),
            int(r.get("n", 0)),
        ),
        "tag": lambda k: f"{k[0]} {k[1]} {k[2]}x{k[3]} n={k[4]}",
        "metrics": [
            ("ns_per_op", "ns/op", 1.0),
            ("bytes_read", "bytes read", 1.0),
        ],
    },
}


def load(path):
    with open(path) as f:
        doc = json.load(f)
    kind = doc.get("bench", "generation_speed")
    schema = SCHEMAS.get(kind, SCHEMAS["generation_speed"])
    return kind, schema, {schema["key"](r): r for r in doc.get("runs", [])}


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        prev_kind, _, prev = load(argv[1])
    except OSError as e:
        # No previous run cached (first build on a branch) — nothing to diff.
        print(f"no previous benchmark to diff against ({e}); skipping")
        return 0
    cur_kind, schema, cur = load(argv[2])
    if prev_kind != cur_kind:
        print(f"bench kind changed ({prev_kind} -> {cur_kind}); nothing comparable")
        return 0

    for k in sorted(set(prev) | set(cur)):
        tag = schema["tag"](k)
        if k not in prev:
            print(f"[added]   {tag}")
            continue
        if k not in cur:
            print(f"[removed] {tag}")
            continue
        parts = []
        for field, label, scale in schema["metrics"]:
            old = prev[k].get(field)
            new = cur[k].get(field)
            if old is None or new is None:
                continue
            delta = (new - old) / old * 100.0 if old else float("inf")
            parts.append(f"{label} {old * scale:.2f} -> {new * scale:.2f} ({delta:+.1f}%)")
        print(f"{tag}")
        for p in parts:
            print(f"    {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
