#!/usr/bin/env python3
"""Diff two BENCH_generation.json files produced by
`cargo bench --bench generation_speed` (stdlib only — CI has no extra
Python packages).

Usage:
    python3 scripts/bench_diff.py PREVIOUS.json CURRENT.json

Runs are keyed by (max_batch, workers). For each key present in both
files the script prints tok/s and queue/compute p50/p95/p99 deltas;
keys only in one file are listed as added/removed. Exit code is always
0 — the diff is informational trend tracking, not a gate (wall-clock
numbers on shared CI runners are too noisy to fail a build on).
"""

import json
import sys


def key(run):
    return (int(run.get("max_batch", 0)), int(run.get("workers", 0)))


METRICS = [
    ("tok_s", "tok/s", 1.0),
    ("queue_p50_s", "queue p50 (ms)", 1e3),
    ("queue_p95_s", "queue p95 (ms)", 1e3),
    ("queue_p99_s", "queue p99 (ms)", 1e3),
    ("compute_p50_s", "compute p50 (ms)", 1e3),
    ("compute_p95_s", "compute p95 (ms)", 1e3),
    ("compute_p99_s", "compute p99 (ms)", 1e3),
]


def load(path):
    with open(path) as f:
        doc = json.load(f)
    return {key(r): r for r in doc.get("runs", [])}


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        prev = load(argv[1])
    except OSError as e:
        # No previous run cached (first build on a branch) — nothing to diff.
        print(f"no previous benchmark to diff against ({e}); skipping")
        return 0
    cur = load(argv[2])

    for k in sorted(set(prev) | set(cur)):
        tag = f"max_batch={k[0]} workers={k[1]}"
        if k not in prev:
            print(f"[added]   {tag}: tok/s {cur[k].get('tok_s', 0.0):.1f}")
            continue
        if k not in cur:
            print(f"[removed] {tag}")
            continue
        parts = []
        for field, label, scale in METRICS:
            old = prev[k].get(field)
            new = cur[k].get(field)
            if old is None or new is None:
                continue
            delta = (new - old) / old * 100.0 if old else float("inf")
            parts.append(f"{label} {old * scale:.2f} -> {new * scale:.2f} ({delta:+.1f}%)")
        print(f"{tag}")
        for p in parts:
            print(f"    {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
